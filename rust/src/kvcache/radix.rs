//! Block-granular radix tree with refcount pinning and lazy-heap LRU
//! eviction.
//!
//! Each node is one KV$ block (BLOCK_TOKENS tokens) identified by its
//! chained hash; a path from the root is a cached prefix. Running
//! sequences *pin* their path (refcount) so eviction can never drop blocks
//! a batch is using — the same invariant vLLM's BlockManager maintains.

use std::collections::{BinaryHeap, HashMap};

use crate::util::FastHash;

const ROOT: usize = 0;

#[derive(Debug)]
struct Node {
    hash: u64,
    parent: usize,
    children: HashMap<u64, usize, FastHash>,
    refcount: u32,
    last_access: u64,
    alive: bool,
}

/// Max-heap entry ordered by *oldest* access first (reverse ordering).
#[derive(Debug, PartialEq, Eq)]
struct EvictCandidate {
    last_access: u64,
    node: usize,
}

impl Ord for EvictCandidate {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; we want the OLDEST access on top.
        other
            .last_access
            .cmp(&self.last_access)
            .then(other.node.cmp(&self.node))
    }
}
impl PartialOrd for EvictCandidate {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Result of one [`RadixTree::admit_chain`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitOutcome {
    /// Leading blocks that were already cached before this admission —
    /// the KV$ hit the sequence's prefill is spared.
    pub hit_blocks: usize,
    /// Leading blocks resident (and pinned) after the admission: the hit
    /// prefix plus newly allocated blocks. Less than the chain length
    /// when pinned-full capacity pressure truncated the insertion.
    pub resident: usize,
}

/// Prefix tree over block-hash chains with capacity + LRU eviction.
#[derive(Debug)]
pub struct RadixTree {
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// Capacity in blocks (0 = unbounded, used for "infinite KV$" studies
    /// like the paper's Fig. 5 hit-rate characterization).
    capacity: usize,
    used: usize,
    evict_heap: BinaryHeap<EvictCandidate>,
    /// Cumulative counters for hit-rate accounting.
    pub total_lookup_blocks: u64,
    pub total_hit_blocks: u64,
    pub total_evicted_blocks: u64,
    /// Number of [`Self::admit_chain`] walks performed. The engine's
    /// admission path is exactly one fused walk per admitted sequence, so
    /// after a run this equals the number of admissions — the harness
    /// asserts it (previously each admission cost three separate walks:
    /// match → insert → match).
    pub admit_radix_walks: u64,
}

impl RadixTree {
    /// `capacity_blocks` = 0 means unbounded.
    pub fn new(capacity_blocks: usize) -> Self {
        RadixTree {
            nodes: vec![Node {
                hash: 0,
                parent: ROOT,
                children: HashMap::default(),
                refcount: 1, // root is never evictable
                last_access: 0,
                alive: true,
            }],
            free: Vec::new(),
            capacity: capacity_blocks,
            used: 0,
            evict_heap: BinaryHeap::new(),
            total_lookup_blocks: 0,
            total_hit_blocks: 0,
            total_evicted_blocks: 0,
            admit_radix_walks: 0,
        }
    }

    pub fn used_blocks(&self) -> usize {
        self.used
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity
    }

    /// Number of leading blocks of `hashes` present in the tree.
    /// With `touch`, refreshes LRU timestamps along the matched path.
    pub fn match_prefix(&mut self, hashes: &[u64], now: u64, touch: bool) -> usize {
        let mut cur = ROOT;
        let mut matched = 0;
        for h in hashes {
            match self.nodes[cur].children.get(h) {
                Some(&next) => {
                    cur = next;
                    matched += 1;
                    if touch {
                        self.touch(next, now);
                    }
                }
                None => break,
            }
        }
        self.total_lookup_blocks += hashes.len() as u64;
        self.total_hit_blocks += matched as u64;
        matched
    }

    /// Read-only prefix probe: number of leading blocks of `hashes`
    /// present, with NO LRU refresh and NO hit-rate accounting. The
    /// enqueue-time hit *estimate* must not perturb eviction order (the
    /// authoritative, LRU-touching match happens at admission), and a
    /// `&self` probe keeps read-side callers free of `&mut` plumbing.
    pub fn peek_prefix(&self, hashes: &[u64]) -> usize {
        let mut cur = ROOT;
        let mut matched = 0;
        for h in hashes {
            match self.nodes[cur].children.get(h) {
                Some(&next) => {
                    cur = next;
                    matched += 1;
                }
                None => break,
            }
        }
        matched
    }

    /// Fused admission walk: in ONE pass over `hashes`, (a) count and
    /// LRU-refresh the already-cached prefix, (b) allocate the remainder
    /// (evicting as needed, truncating under pinned-full pressure), and
    /// (c) pin every resident block for the sequence's lifetime. This
    /// replaces the engine's previous `match_prefix` → `insert` →
    /// `match_prefix` → `pin` quadruple walk with identical
    /// eviction-visible semantics:
    ///
    /// * Existing blocks get `last_access = now` and `refcount += 1`. No
    ///   eviction candidate is pushed while pinned — the stale entry the
    ///   old path pushed was unusable anyway (refcount check), and
    ///   `unpin` re-registers the tail when the pin is released.
    /// * New blocks are born pinned (`refcount = 1`), which also makes
    ///   the old path's protect-the-fresh-leaf parking in `evict_one`
    ///   unnecessary for them.
    /// * Release with `unpin(&hashes, outcome.resident, now)` exactly as
    ///   before.
    ///
    /// Counters: one lookup of `hashes.len()` blocks with `hit_blocks`
    /// hits (the old path triple-counted lookups).
    pub fn admit_chain(&mut self, hashes: &[u64], now: u64) -> AdmitOutcome {
        self.admit_radix_walks += 1;
        let mut cur = ROOT;
        let mut hit = 0usize;
        let mut resident = 0usize;
        // Phase 1 (cached prefix): refresh, count, pin. After the first
        // miss every lookup misses (new nodes have no children), so the
        // same loop becomes phase 2: allocate, born pinned.
        for h in hashes {
            match self.nodes[cur].children.get(h) {
                Some(&next) => {
                    let n = &mut self.nodes[next];
                    n.last_access = now;
                    n.refcount += 1;
                    hit += 1;
                    resident += 1;
                    cur = next;
                }
                None => {
                    // Phase 2: allocate the remainder, born pinned.
                    if self.capacity != 0 && self.used >= self.capacity && !self.evict_one(cur) {
                        break; // full and nothing evictable: truncate
                    }
                    let idx = self.alloc(Node {
                        hash: *h,
                        parent: cur,
                        children: HashMap::default(),
                        refcount: 1,
                        last_access: now,
                        alive: true,
                    });
                    self.nodes[cur].children.insert(*h, idx);
                    self.used += 1;
                    resident += 1;
                    cur = idx;
                }
            }
        }
        self.total_lookup_blocks += hashes.len() as u64;
        self.total_hit_blocks += hit as u64;
        self.maybe_compact_heap();
        AdmitOutcome {
            hit_blocks: hit,
            resident,
        }
    }

    fn touch(&mut self, node: usize, now: u64) {
        self.nodes[node].last_access = now;
        // Unbounded trees never evict, so feeding their heap would only
        // grow it by one entry per repeated touch, forever.
        if self.capacity != 0
            && self.nodes[node].refcount == 0
            && self.nodes[node].children.is_empty()
        {
            self.evict_heap.push(EvictCandidate {
                last_access: now,
                node,
            });
            self.maybe_compact_heap();
        }
    }

    /// Insert the full chain, evicting LRU leaves as needed. Returns the
    /// number of NEW blocks allocated (0 = fully cached already). If the
    /// cache cannot free enough space (everything pinned), inserts as many
    /// leading blocks as fit.
    pub fn insert(&mut self, hashes: &[u64], now: u64) -> usize {
        let mut cur = ROOT;
        let mut created = 0;
        for h in hashes {
            if let Some(&next) = self.nodes[cur].children.get(h) {
                self.nodes[next].last_access = now;
                // Refreshing an already-present free leaf invalidates its
                // standing heap entry (lazy validation compares
                // last_access), so it must be re-pushed here or it becomes
                // permanently unevictable: under churn the heap drains and
                // inserts truncate while unpinned leaves still exist.
                // (Capacity-0 trees never evict: skip the push or the heap
                // grows by one entry per repeated insert, unbounded.)
                if self.capacity != 0
                    && self.nodes[next].refcount == 0
                    && self.nodes[next].children.is_empty()
                {
                    self.evict_heap.push(EvictCandidate {
                        last_access: now,
                        node: next,
                    });
                }
                cur = next;
                continue;
            }
            if self.capacity != 0 && self.used >= self.capacity && !self.evict_one(cur) {
                break; // full and nothing evictable
            }
            let idx = self.alloc(Node {
                hash: *h,
                parent: cur,
                children: HashMap::default(),
                refcount: 0,
                last_access: now,
                alive: true,
            });
            self.nodes[cur].children.insert(*h, idx);
            if self.capacity != 0 {
                self.evict_heap.push(EvictCandidate {
                    last_access: now,
                    node: idx,
                });
            }
            self.used += 1;
            created += 1;
            cur = idx;
        }
        self.maybe_compact_heap();
        created
    }

    /// Pin the first `blocks` blocks of the chain (they must be present —
    /// call right after `insert`). Pinned blocks cannot be evicted.
    pub fn pin(&mut self, hashes: &[u64], blocks: usize) {
        let mut cur = ROOT;
        for h in hashes.iter().take(blocks) {
            match self.nodes[cur].children.get(h) {
                Some(&next) => {
                    self.nodes[next].refcount += 1;
                    cur = next;
                }
                None => break, // insert was truncated by capacity
            }
        }
    }

    /// Release a previous pin.
    pub fn unpin(&mut self, hashes: &[u64], blocks: usize, now: u64) {
        let mut cur = ROOT;
        for h in hashes.iter().take(blocks) {
            match self.nodes[cur].children.get(h) {
                Some(&next) => {
                    let n = &mut self.nodes[next];
                    debug_assert!(n.refcount > 0, "unpin without pin");
                    n.refcount = n.refcount.saturating_sub(1);
                    n.last_access = now;
                    cur = next;
                }
                None => break,
            }
        }
        // Re-register the tail as an eviction candidate if it became free.
        if self.capacity != 0
            && cur != ROOT
            && self.nodes[cur].refcount == 0
            && self.nodes[cur].children.is_empty()
        {
            self.evict_heap.push(EvictCandidate {
                last_access: now,
                node: cur,
            });
        }
        self.maybe_compact_heap();
    }

    /// Evict one LRU unpinned leaf. `protect` (and its ancestors) are the
    /// path currently being inserted — never evict it. Returns false if
    /// nothing is evictable.
    fn evict_one(&mut self, protect: usize) -> bool {
        // At most one still-valid heap entry can refer to the protected
        // node (older duplicates fail the last_access check). Park it and
        // restore it on exit: protection must SKIP the candidate, not
        // discard it — dropping it left the tail leaf of a truncated
        // insert permanently unevictable (empty heap, nothing ever
        // re-pushes it on the router path, which never pins/unpins).
        let mut deferred: Option<EvictCandidate> = None;
        let mut evicted = false;
        while let Some(cand) = self.evict_heap.pop() {
            let n = &self.nodes[cand.node];
            // Lazy validation: the entry must still describe reality.
            if !n.alive
                || n.refcount != 0
                || !n.children.is_empty()
                || n.last_access != cand.last_access
            {
                continue; // stale: drop
            }
            if cand.node == protect {
                deferred = Some(cand);
                continue;
            }
            let parent = n.parent;
            let hash = n.hash;
            self.nodes[cand.node].alive = false;
            self.nodes[parent].children.remove(&hash);
            self.free.push(cand.node);
            self.used -= 1;
            self.total_evicted_blocks += 1;
            // Parent may now be an evictable leaf.
            let p = &self.nodes[parent];
            if parent != ROOT && p.alive && p.refcount == 0 && p.children.is_empty() {
                self.evict_heap.push(EvictCandidate {
                    last_access: p.last_access,
                    node: parent,
                });
            }
            evicted = true;
            break;
        }
        if let Some(c) = deferred {
            self.evict_heap.push(c);
        }
        evicted
    }

    /// Compact the lazy heap when stale entries dominate. Below capacity
    /// nothing ever pops, so refresh re-pushes (one per repeated insert /
    /// touch / unpin) would otherwise accumulate without bound. Dropping
    /// entries that fail validation NOW is behavior-preserving:
    /// `last_access` only moves forward (a stale entry can never validate
    /// later), and every transition that makes a node evictable again —
    /// last child evicted, unpin, refresh — pushes a fresh entry.
    fn maybe_compact_heap(&mut self) {
        if self.evict_heap.len() <= 4 * self.used.max(16) {
            return;
        }
        let old = std::mem::take(&mut self.evict_heap);
        self.evict_heap = old
            .into_iter()
            .filter(|c| {
                let n = &self.nodes[c.node];
                n.alive
                    && n.refcount == 0
                    && n.children.is_empty()
                    && n.last_access == c.last_access
            })
            .collect();
    }

    fn alloc(&mut self, node: Node) -> usize {
        if let Some(idx) = self.free.pop() {
            self.nodes[idx] = node;
            idx
        } else {
            self.nodes.push(node);
            self.nodes.len() - 1
        }
    }

    /// Lifetime block hit rate (blocks matched / blocks looked up).
    pub fn hit_rate(&self) -> f64 {
        if self.total_lookup_blocks == 0 {
            0.0
        } else {
            self.total_hit_blocks as f64 / self.total_lookup_blocks as f64
        }
    }

    /// Invariant checker used by the property tests.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut live = 0usize;
        for (i, n) in self.nodes.iter().enumerate() {
            if !n.alive {
                continue;
            }
            if i != ROOT {
                live += 1;
                let p = &self.nodes[n.parent];
                if !p.alive {
                    return Err(format!("node {i} has dead parent {}", n.parent));
                }
                if p.children.get(&n.hash) != Some(&i) {
                    return Err(format!("node {i} not linked from parent"));
                }
            }
            for (&h, &c) in &n.children {
                let ch = &self.nodes[c];
                if !ch.alive || ch.parent != i || ch.hash != h {
                    return Err(format!("bad child link {i}->{c}"));
                }
            }
        }
        if live != self.used {
            return Err(format!("used={} but live={}", self.used, live));
        }
        if self.capacity != 0 && self.used > self.capacity {
            return Err(format!("over capacity: {}>{}", self.used, self.capacity));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn match_empty() {
        let mut t = RadixTree::new(0);
        assert_eq!(t.match_prefix(&[1, 2, 3], 0, false), 0);
    }

    #[test]
    fn insert_then_match() {
        let mut t = RadixTree::new(0);
        assert_eq!(t.insert(&[1, 2, 3], 0), 3);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4], 1, false), 3);
        assert_eq!(t.match_prefix(&[1, 2], 1, false), 2);
        assert_eq!(t.match_prefix(&[9], 1, false), 0);
        assert_eq!(t.used_blocks(), 3);
        t.check_invariants().unwrap();
    }

    #[test]
    fn insert_idempotent() {
        let mut t = RadixTree::new(0);
        t.insert(&[1, 2, 3], 0);
        assert_eq!(t.insert(&[1, 2, 3], 1), 0);
        assert_eq!(t.insert(&[1, 2, 3, 4], 2), 1);
        assert_eq!(t.used_blocks(), 4);
    }

    #[test]
    fn branching_prefixes() {
        let mut t = RadixTree::new(0);
        t.insert(&[1, 2, 3], 0);
        t.insert(&[1, 2, 9, 9], 1);
        assert_eq!(t.used_blocks(), 5); // 1,2 shared; 3 + 9,9 distinct
        assert_eq!(t.match_prefix(&[1, 2, 9, 9], 2, false), 4);
        t.check_invariants().unwrap();
    }

    #[test]
    fn lru_eviction_prefers_oldest() {
        let mut t = RadixTree::new(4);
        t.insert(&[1, 2], 0); // old chain
        t.insert(&[10, 20], 100); // newer chain
        // Inserting 1 more block must evict the oldest leaf (2).
        t.insert(&[30], 200);
        assert_eq!(t.used_blocks(), 4);
        assert_eq!(t.match_prefix(&[1, 2], 300, false), 1, "leaf 2 evicted");
        assert_eq!(t.match_prefix(&[10, 20], 300, false), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn pinned_blocks_survive_pressure() {
        let mut t = RadixTree::new(3);
        t.insert(&[1, 2, 3], 0);
        t.pin(&[1, 2, 3], 3);
        // Cache full of pinned blocks: new insert can't allocate.
        assert_eq!(t.insert(&[7, 8], 10), 0);
        assert_eq!(t.match_prefix(&[1, 2, 3], 20, false), 3);
        // After unpin, pressure can evict.
        t.unpin(&[1, 2, 3], 3, 30);
        assert_eq!(t.insert(&[7, 8], 40), 2);
        assert!(t.used_blocks() <= 3);
        t.check_invariants().unwrap();
    }

    #[test]
    fn eviction_is_leaf_only() {
        let mut t = RadixTree::new(3);
        t.insert(&[1, 2, 3], 0);
        t.insert(&[5], 10); // forces evicting leaf 3, not inner 1/2
        assert_eq!(t.match_prefix(&[1, 2], 20, false), 2);
        assert_eq!(t.match_prefix(&[1, 2, 3], 20, false), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn touch_protects_from_eviction() {
        let mut t = RadixTree::new(4);
        t.insert(&[1, 2], 0);
        t.insert(&[10, 20], 10);
        t.match_prefix(&[1, 2], 100, true); // refresh chain 1-2
        t.insert(&[30], 200); // should evict from chain 10-20 now
        assert_eq!(t.match_prefix(&[1, 2], 300, false), 2);
        assert_eq!(t.match_prefix(&[10, 20], 300, false), 1);
        t.check_invariants().unwrap();
    }

    #[test]
    fn hit_rate_accounting() {
        let mut t = RadixTree::new(0);
        t.insert(&[1, 2], 0);
        t.match_prefix(&[1, 2], 1, false); // 2/2
        t.match_prefix(&[9, 9], 1, false); // 0/2
        assert!((t.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn capacity_zero_unbounded() {
        let mut t = RadixTree::new(0);
        let chain: Vec<u64> = (0..10_000).collect();
        t.insert(&chain, 0);
        assert_eq!(t.used_blocks(), 10_000);
        t.check_invariants().unwrap();
    }

    /// Regression for the eviction-starvation bug: `insert` used to
    /// refresh `last_access` on already-present leaves WITHOUT re-pushing
    /// an eviction candidate. The stale heap entry then failed
    /// `evict_one`'s lazy validation (`last_access != cand.last_access`),
    /// the heap drained, and the refreshed leaf became permanently
    /// unevictable — inserts truncated ("full and nothing evictable")
    /// while unpinned leaves existed.
    #[test]
    fn reinserted_chain_stays_evictable() {
        let mut t = RadixTree::new(2);
        t.insert(&[1, 2], 0);
        assert_eq!(t.used_blocks(), 2);
        // Re-insert the same chain: pure refresh, no new blocks. Under the
        // old code this silently dropped leaf 2 from the eviction heap.
        assert_eq!(t.insert(&[1, 2], 5), 0);
        // A new chain must still be able to evict its way in.
        assert_eq!(t.insert(&[9], 10), 1, "eviction starved after refresh");
        assert_eq!(t.match_prefix(&[9], 20, false), 1);
        assert_eq!(t.match_prefix(&[1, 2], 20, false), 1, "leaf 2 evicted");
        assert_eq!(t.total_evicted_blocks, 1);
        t.check_invariants().unwrap();
    }

    /// Residual starvation shape: a truncated insert pops the protected
    /// path tail as an (otherwise valid) eviction candidate. Dropping
    /// that entry — instead of parking and restoring it — left the tail
    /// leaf permanently unevictable on paths that never pin/unpin (the
    /// router views), with the heap fully drained.
    #[test]
    fn truncated_insert_keeps_tail_evictable() {
        let mut t = RadixTree::new(2);
        // 3-block chain into a 2-block tree: block 3 triggers eviction
        // with the freshly created leaf 2 protected; the insert truncates.
        assert_eq!(t.insert(&[1, 2, 3], 10), 2);
        assert_eq!(t.used_blocks(), 2);
        // Leaf 2 must still be evictable by a later insert.
        assert_eq!(t.insert(&[9], 20), 1, "protected candidate was discarded");
        assert_eq!(t.match_prefix(&[9], 30, false), 1);
        assert_eq!(t.total_evicted_blocks, 1);
        t.check_invariants().unwrap();
    }

    /// Same starvation shape through repeated refresh cycles: every
    /// resident leaf is refreshed (invalidating every standing heap
    /// entry), then an over-capacity insert must still evict.
    #[test]
    fn refresh_cycles_never_starve_eviction() {
        let mut t = RadixTree::new(8);
        t.insert(&[1, 2, 3, 4], 0);
        t.insert(&[10, 20, 30, 40], 1);
        assert_eq!(t.used_blocks(), 8);
        for round in 0..5u64 {
            let now = 10 + round;
            // Refresh both resident chains (no allocation, pure touch).
            let r1 = t.match_prefix(&[1, 2, 3, 4], now, false);
            assert_eq!(t.insert(&[1, 2, 3, 4][..r1], now), 0);
            let r2 = t.match_prefix(&[10, 20, 30, 40], now, false);
            assert_eq!(t.insert(&[10, 20, 30, 40][..r2], now), 0);
            // Over-capacity probe: must always evict exactly one block.
            assert_eq!(t.insert(&[1000 + round], 100 + round), 1, "starved at round {round}");
            assert_eq!(t.used_blocks(), 8);
        }
        assert!(t.total_evicted_blocks >= 5);
        t.check_invariants().unwrap();
    }

    /// Below capacity nothing ever pops the lazy heap, so the refresh
    /// re-push (starvation fix) must not let it grow with request count.
    #[test]
    fn refresh_heap_stays_bounded_below_capacity() {
        let mut t = RadixTree::new(1024);
        t.insert(&[1, 2, 3], 0);
        for now in 1..5000u64 {
            t.insert(&[1, 2, 3], now); // pure refresh, one push each
        }
        assert!(
            t.evict_heap.len() <= 4 * t.used_blocks().max(16),
            "heap leaked: {} entries for {} blocks",
            t.evict_heap.len(),
            t.used_blocks()
        );
        // Compaction must not have cost evictability.
        let mut full = RadixTree::new(3);
        full.insert(&[1, 2, 3], 0);
        for now in 1..5000u64 {
            full.insert(&[1, 2, 3], now);
        }
        assert_eq!(full.insert(&[9], 9000), 1);
        full.check_invariants().unwrap();
    }

    #[test]
    fn peek_prefix_matches_match_without_perturbing_lru() {
        let mut t = RadixTree::new(4);
        t.insert(&[1, 2], 0); // old chain
        t.insert(&[10, 20], 100); // newer chain
        assert_eq!(t.peek_prefix(&[1, 2, 3]), 2);
        assert_eq!(t.peek_prefix(&[9]), 0);
        // A peek at the old chain must NOT refresh it: the next eviction
        // still takes leaf 2 (oldest), unlike a touching match_prefix.
        t.peek_prefix(&[1, 2]);
        t.insert(&[30], 200);
        assert_eq!(t.match_prefix(&[1, 2], 300, false), 1, "peek must not protect");
        assert_eq!(t.match_prefix(&[10, 20], 300, false), 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn peek_prefix_leaves_counters_untouched() {
        let mut t = RadixTree::new(0);
        t.insert(&[1, 2], 0);
        let (lk, ht) = (t.total_lookup_blocks, t.total_hit_blocks);
        assert_eq!(t.peek_prefix(&[1, 2]), 2);
        assert_eq!((t.total_lookup_blocks, t.total_hit_blocks), (lk, ht));
    }

    /// The fused walk must be observationally equivalent to the old
    /// match→insert→match→pin quadruple on the full admit/release cycle.
    #[test]
    fn admit_chain_equals_quadruple_walk() {
        let mut ops: Vec<(u64, Vec<u64>)> = Vec::new();
        let mut rng = crate::util::Rng::new(7);
        for step in 0..600u64 {
            let base = rng.gen_range(0, 6);
            let len = rng.gen_range(1, 10) as usize;
            let chain: Vec<u64> = (0..len as u64).map(|i| base * 1000 + i).collect();
            ops.push((step, chain));
        }
        for cap in [0usize, 8, 32, 128] {
            let mut fused = RadixTree::new(cap);
            let mut quad = RadixTree::new(cap);
            for (now, chain) in &ops {
                let out = fused.admit_chain(chain, *now);
                let hit = quad.match_prefix(chain, *now, true);
                quad.insert(chain, *now);
                let resident = quad.match_prefix(chain, *now, false);
                quad.pin(chain, resident);
                assert_eq!(out.hit_blocks, hit, "cap {cap} @ {now}");
                assert_eq!(out.resident, resident, "cap {cap} @ {now}");
                // Immediate release (the engine holds pins across a seq's
                // lifetime; interleaved pin lifetimes are covered by the
                // churn test below).
                fused.unpin(chain, out.resident, now + 1);
                quad.unpin(chain, resident, now + 1);
                assert_eq!(fused.used_blocks(), quad.used_blocks());
                // Identical future behavior: every chain probes the same.
                for (_, probe) in ops.iter().take(12) {
                    assert_eq!(fused.peek_prefix(probe), quad.peek_prefix(probe));
                }
                fused.check_invariants().unwrap();
                quad.check_invariants().unwrap();
            }
            assert_eq!(fused.total_evicted_blocks, quad.total_evicted_blocks);
        }
    }

    #[test]
    fn admit_chain_pins_and_truncates_under_pressure() {
        let mut t = RadixTree::new(3);
        // 5-block chain into a 3-block tree: truncated, resident pinned.
        let out = t.admit_chain(&[1, 2, 3, 4, 5], 0);
        assert_eq!((out.hit_blocks, out.resident), (0, 3));
        // Everything resident is pinned: a new chain cannot evict in.
        assert_eq!(t.insert(&[9], 10), 0);
        t.unpin(&[1, 2, 3, 4, 5], out.resident, 20);
        // Released: evictable again.
        assert_eq!(t.insert(&[9], 30), 1);
        // Re-admit over the partial chain: hit = what survived.
        let hit = t.peek_prefix(&[1, 2, 3]);
        let out2 = t.admit_chain(&[1, 2, 3], 40);
        assert_eq!(out2.hit_blocks, hit);
        assert!(out2.resident >= out2.hit_blocks);
        assert_eq!(t.admit_radix_walks, 2);
        t.check_invariants().unwrap();
    }

    #[test]
    fn heavy_churn_keeps_invariants() {
        let mut t = RadixTree::new(64);
        let mut rng = crate::util::Rng::new(42);
        let mut last_evicted = 0u64;
        for step in 0..2000u64 {
            let base = rng.gen_range(0, 8);
            let len = rng.gen_range(1, 12) as usize;
            let chain: Vec<u64> = (0..len as u64).map(|i| base * 1000 + i).collect();
            match rng.gen_range(0, 3) {
                0 => {
                    t.insert(&chain, step);
                }
                1 => {
                    t.match_prefix(&chain, step, true);
                }
                _ => {
                    t.insert(&chain, step);
                    t.pin(&chain, len);
                    t.unpin(&chain, len, step + 1);
                }
            }
            // Lifetime eviction counter is monotone under churn.
            assert!(t.total_evicted_blocks >= last_evicted);
            last_evicted = t.total_evicted_blocks;
            if step % 101 == 0 {
                t.check_invariants().unwrap();
            }
        }
        t.check_invariants().unwrap();
        assert!(t.used_blocks() <= 64);
        assert!(t.total_evicted_blocks > 0);
        // Eviction never starves: everything is unpinned by now, so an
        // over-capacity insert of a fresh chain must always evict its way
        // in rather than truncate.
        let evicted_before = t.total_evicted_blocks;
        let probe: Vec<u64> = (0..64u64).map(|i| 999_000 + i).collect();
        assert_eq!(t.insert(&probe, 10_000), 64, "eviction starved after churn");
        assert!(t.total_evicted_blocks > evicted_before);
        assert!(t.used_blocks() <= 64);
        t.check_invariants().unwrap();
    }
}
