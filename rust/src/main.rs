//! `lmetric` — the launcher.
//!
//! Subcommands:
//!   replay       run one policy on one workload through the DES cluster
//!   sessions     closed-loop session replay (reactive turn release)
//!   open         open-arrival replay: rate programs, admission, goodput
//!   faults       replay under a lifecycle fault plan (crash/drain/scale)
//!                with optional reactive autoscaling
//!   compare      run every policy on one workload, print the table
//!   serve        live cluster: real PJRT transformer, wall-clock latencies
//!   gen-trace    write a synthetic workload as jsonl
//!   trace-stats  Fig-5-style characterization of a workload
//!   calibrate    analytic cost model vs. real PJRT step timings

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use lmetric::cluster::live::{run_live, LiveClusterConfig};
use lmetric::cluster::{self, run_des, AdmissionPolicy, RunSpec};
use lmetric::config::{ConfigDoc, ExperimentConfig, FleetSpec};
use lmetric::engine::ModelProfile;
use lmetric::metrics::{render_table, ResultRow, SloSpec};
use lmetric::policy;
use lmetric::trace::{generate, load_jsonl, save_jsonl, Workload, WorkloadSpec};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn exp_from_flags(flags: &HashMap<String, String>) -> ExperimentConfig {
    let mut exp = if let Some(path) = flags.get("config") {
        let doc = ConfigDoc::from_file(path).unwrap_or_else(|e| {
            eprintln!("config: {e}");
            std::process::exit(2);
        });
        ExperimentConfig::from_doc(&doc).unwrap_or_else(|e| {
            eprintln!("config: {e}");
            std::process::exit(2);
        })
    } else {
        ExperimentConfig::default()
    };
    if let Some(v) = flags.get("workload") {
        exp.workload = v.clone();
    }
    if let Some(v) = flags.get("policy") {
        exp.policy = v.clone();
    }
    if let Some(v) = flags.get("instances") {
        exp.instances = v.parse().expect("--instances");
    }
    if let Some(v) = flags.get("requests") {
        exp.requests = v.parse().expect("--requests");
    }
    if let Some(v) = flags.get("rate-scale") {
        exp.rate_scale = v.parse().expect("--rate-scale");
    }
    if let Some(v) = flags.get("param") {
        exp.param = v.parse().expect("--param");
    }
    if let Some(v) = flags.get("profile") {
        exp.profile = v.clone();
    }
    if let Some(v) = flags.get("seed") {
        exp.seed = v.parse().expect("--seed");
    }
    // `--fleet h100:2,l40:6` wins over `--instances` (the spec carries
    // its own size); mirrors the TOML `[fleet] spec` key.
    if let Some(v) = flags.get("fleet") {
        let fleet = FleetSpec::parse(v).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
        exp.instances = fleet.n_instances();
        exp.fleet = Some(fleet);
    }
    if let Some(v) = flags.get("n-models") {
        exp.n_models = v.parse::<usize>().expect("--n-models").max(1);
    }
    if let Some(v) = flags.get("queue-policy") {
        exp.queue_policy = v.clone();
    }
    // Validate here so a typo surfaces as the registry's name-listing
    // error instead of a panic inside Instance::new.
    if let Err(e) = lmetric::engine::queue::build(&exp.queue_policy) {
        eprintln!("{e}");
        std::process::exit(2);
    }
    exp
}

/// `--admission NAME [--admission-param F]` → an admission policy, or
/// `None` when the flag is absent (admit everything, legacy behaviour).
fn admission_from_flags(
    flags: &HashMap<String, String>,
    profile: &ModelProfile,
) -> Option<Box<dyn AdmissionPolicy>> {
    let name = flags.get("admission")?;
    let param: f64 = flags
        .get("admission-param")
        .map(|v| v.parse().expect("--admission-param"))
        .unwrap_or_else(|| cluster::default_admission_param(name));
    let adm = cluster::build_admission(name, param, profile).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    Some(adm)
}

/// `--slo-ttft S` / `--slo-tpot S` (seconds) → an [`SloSpec`]; a missing
/// bound is unconstrained.
fn slo_from_flags(flags: &HashMap<String, String>) -> Option<SloSpec> {
    let ttft: Option<f64> = flags.get("slo-ttft").map(|v| v.parse().expect("--slo-ttft"));
    let tpot: Option<f64> = flags.get("slo-tpot").map(|v| v.parse().expect("--slo-tpot"));
    if ttft.is_none() && tpot.is_none() {
        return None;
    }
    let slo = SloSpec::new(ttft.unwrap_or(f64::INFINITY), tpot.unwrap_or(f64::INFINITY));
    Some(slo)
}

/// Shared overload/goodput epilogue for `replay`, `sessions` and `open`.
fn print_overload_summary(m: &lmetric::metrics::RunMetrics) {
    if let Some(name) = &m.admission_name {
        let o = m.overload;
        println!(
            "admission {name}: offered {}, admitted {}, shed {} \
             ({} whole sessions, {} mid-session, {} orphaned turns)",
            o.offered, o.admitted, o.shed, o.shed_sessions, o.shed_mid_session, o.orphaned_turns
        );
    }
    if let Some(slo) = m.slo {
        println!(
            "goodput: {:.1}% of offered within SLO (ttft ≤ {:.2}s, tpot ≤ {:.3}s), \
             {:.2} good req/s",
            m.goodput_ratio(slo) * 100.0,
            slo.ttft_s,
            slo.tpot_s,
            m.goodput_rps(slo)
        );
    }
}

fn cmd_replay(flags: &HashMap<String, String>) {
    let exp = exp_from_flags(flags);
    let profile = ModelProfile::by_name(&exp.profile).expect("profile");
    let mut pol =
        policy::build(&exp.policy, exp.param, &profile, exp.chunk_budget).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    println!(
        "replaying {} ({} reqs) on {}×{} under {} ...",
        exp.workload, exp.requests, exp.instances, exp.profile, pol.name()
    );
    let trace = cluster::build_scaled_trace(&exp);
    let cfg = cluster::cluster_config(&exp);
    let mut spec = RunSpec::open_loop(&cfg, &trace);
    if let Some(adm) = admission_from_flags(flags, &profile) {
        spec = spec.with_admission(adm);
    }
    if let Some(slo) = slo_from_flags(flags) {
        spec = spec.with_slo(slo);
    }
    let m = cluster::run(spec, pol.as_mut());
    let row = ResultRow::from_metrics(&pol.name(), &m)
        .with("throughput_tok_s", m.output_throughput())
        .with("imbalance_s", m.imbalance_score());
    println!("{}", render_table(&format!("{} / {}", exp.workload, exp.profile), &[row]));
    if pol.guard_counters().is_some() {
        let g = m.guard;
        println!(
            "guard: {} checks, {} degenerate, {} inversion, {} mitigated",
            g.checks, g.degenerate, g.inversion, g.mitigated
        );
    }
    print_overload_summary(&m);
}

/// Open-arrival replay: Poisson session starts under a rate program,
/// reactive turn release, optional admission control and SLO accounting —
/// the CLI face of the `trace::open` + `cluster::overload` engines.
fn cmd_open(flags: &HashMap<String, String>) {
    use lmetric::cluster::{build_scaled_open, ClusterConfig};
    use lmetric::engine::EngineConfig;
    use lmetric::metrics::SessionMetrics;
    use lmetric::trace::{OpenSpec, RateProgram};

    let shape = flags.get("shape").map(String::as_str).unwrap_or("constant");
    let dur: f64 = flags.get("duration").map(|v| v.parse().unwrap()).unwrap_or(120.0);
    let instances: usize = flags.get("instances").map(|v| v.parse().unwrap()).unwrap_or(8);
    let seed: u64 = flags.get("seed").map(|v| v.parse().unwrap()).unwrap_or(42);
    let rate_scale: f64 = flags.get("rate-scale").map(|v| v.parse().unwrap()).unwrap_or(0.8);
    let cap: usize = flags.get("requests").map(|v| v.parse().unwrap()).unwrap_or(4000);
    let policy_name = flags.get("policy").map(String::as_str).unwrap_or("lmetric");

    let program = match shape {
        "constant" => RateProgram::constant(10.0, dur),
        "ramp" => RateProgram::ramp(2.0, 20.0, dur),
        "diurnal" => RateProgram::diurnal(10.0, 0.6, dur, dur),
        "flash" => RateProgram::flash_crowd(8.0, 6.0, dur * 0.4, dur * 0.2, dur),
        other => {
            eprintln!("unknown shape {other} (try: constant ramp diurnal flash)");
            std::process::exit(2);
        }
    };
    let profile = ModelProfile::moe_30b();
    let mut pol = policy::build_default(policy_name, &profile, 256).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let cfg = ClusterConfig::new(instances, EngineConfig::default());
    let ospec = OpenSpec::new(program, seed).with_cap(cap);
    let strace = build_scaled_open(&ospec, &cfg, rate_scale);
    println!(
        "open-arrival replay: {} ({} sessions / {} turns) at {rate_scale}× capacity \
         on {instances} instances under {}",
        strace.name,
        strace.sessions.len(),
        strace.n_turns(),
        pol.name()
    );
    let mut spec = RunSpec::sessions(&cfg, &strace);
    if let Some(adm) = admission_from_flags(flags, &cfg.engine.profile) {
        spec = spec.with_admission(adm);
    }
    if let Some(slo) = slo_from_flags(flags) {
        spec = spec.with_slo(slo);
    }
    let m = cluster::run(spec, pol.as_mut());
    let sm = SessionMetrics::collect(&m, &strace);
    let row = ResultRow::from_metrics(&pol.name(), &m)
        .with("throughput_tok_s", m.output_throughput())
        .with("affinity", sm.affinity_ratio());
    println!("{}", render_table(&format!("open/{shape}"), &[row]));
    print_overload_summary(&m);
}

fn cmd_sessions(flags: &HashMap<String, String>) {
    use lmetric::cluster::{build_scaled_sessions, run_session_des, ClusterConfig};
    use lmetric::engine::EngineConfig;
    use lmetric::metrics::{fmt_s, SessionMetrics, TURN_CURVE_CAP};
    use lmetric::trace::{SessionKind, SessionSpec};

    let kind = flags
        .get("kind")
        .map(|k| {
            SessionKind::by_name(k).unwrap_or_else(|| {
                eprintln!("unknown session kind {k} (try: chat api coding)");
                std::process::exit(2);
            })
        })
        .unwrap_or(SessionKind::Chat);
    let requests: usize = flags.get("requests").map(|v| v.parse().unwrap()).unwrap_or(2000);
    let instances: usize = flags.get("instances").map(|v| v.parse().unwrap()).unwrap_or(8);
    let seed: u64 = flags.get("seed").map(|v| v.parse().unwrap()).unwrap_or(42);
    let rate_scale: f64 = flags.get("rate-scale").map(|v| v.parse().unwrap()).unwrap_or(0.5);
    let policy_name = flags.get("policy").map(String::as_str).unwrap_or("lmetric");

    let profile = ModelProfile::moe_30b();
    let mut pol = policy::build_default(policy_name, &profile, 256).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let cfg = ClusterConfig::new(instances, EngineConfig::default());
    let spec = SessionSpec::preset(kind, requests, seed);
    let strace = build_scaled_sessions(&spec, &cfg, rate_scale);
    println!(
        "closed-loop replay: {} sessions / {} turns ({}) on {instances} instances under {}",
        strace.sessions.len(),
        strace.n_turns(),
        kind.name(),
        pol.name()
    );
    let m = run_session_des(&cfg, &strace, pol.as_mut());
    let sm = SessionMetrics::collect(&m, &strace);
    let row = ResultRow::from_metrics(&pol.name(), &m)
        .with("affinity", sm.affinity_ratio())
        .with("turn0_hit", sm.turn0_hit())
        .with("late_turn_hit", sm.late_turn_hit());
    println!("{}", render_table(&format!("sessions/{}", kind.name()), &[row]));
    println!(
        "sessions: {} completed, span p50 {}, session-mean TTFT p50 {}",
        sm.sessions,
        fmt_s(sm.session_span_s.p50),
        fmt_s(sm.session_mean_ttft.p50)
    );
    println!(
        "affinity: {:.1}% of consecutive turns stayed on the previous instance",
        sm.affinity_ratio() * 100.0
    );
    println!("per-turn prefix-hit curve:");
    for ti in 0..TURN_CURVE_CAP {
        if sm.turn_hit_counts[ti] == 0 {
            continue;
        }
        println!(
            "  turn {:>3}: {:>5.1}%  ({} samples)",
            if ti == TURN_CURVE_CAP - 1 {
                format!("{ti}+")
            } else {
                ti.to_string()
            },
            sm.turn_hit_curve[ti] * 100.0,
            sm.turn_hit_counts[ti]
        );
    }
}

/// Parse `--plan "crash@12:0,recover@30:0,drain@20:2:5,scaleup@40,scaleup@55:warm"`
/// — comma-separated events at virtual *seconds* — plus an optional
/// stochastic layer (`--crash-rate R --mttr S [--horizon S --fault-seed N]`).
fn plan_from_flags(
    flags: &HashMap<String, String>,
    n_instances: usize,
) -> lmetric::cluster::FaultPlan {
    use lmetric::cluster::{FaultPlan, StochasticFaults};
    fn bail(ev: &str) -> ! {
        eprintln!(
            "bad plan event {ev:?} (try: crash@T:I recover@T:I drain@T:I:DEADLINE scaleup@T[:warm])"
        );
        std::process::exit(2);
    }
    let mut plan = FaultPlan::new();
    if let Some(spec) = flags.get("plan") {
        for ev in spec.split(',').filter(|s| !s.is_empty()) {
            let Some((kind, rest)) = ev.split_once('@') else { bail(ev) };
            let parts: Vec<&str> = rest.split(':').collect();
            let Ok(at_s) = parts[0].parse::<f64>() else { bail(ev) };
            let at_us = (at_s * 1e6) as u64;
            let inst = |k: usize| -> usize {
                parts.get(k).and_then(|v| v.parse().ok()).unwrap_or_else(|| bail(ev))
            };
            plan = match kind {
                "crash" => plan.crash_at(at_us, inst(1)),
                "recover" => plan.recover_at(at_us, inst(1)),
                "drain" => {
                    let Some(Ok(dl_s)) = parts.get(2).map(|v| v.parse::<f64>()) else { bail(ev) };
                    plan.drain_at(at_us, inst(1), (dl_s * 1e6) as u64)
                }
                "scaleup" => plan.scale_up_at(at_us, parts.get(1) != Some(&"warm")),
                _ => bail(ev),
            };
        }
    }
    if let Some(rate) = flags.get("crash-rate") {
        let spec = StochasticFaults {
            seed: flags.get("fault-seed").map(|v| v.parse().expect("--fault-seed")).unwrap_or(7),
            crash_rate_per_s: rate.parse().expect("--crash-rate"),
            mttr_s: flags.get("mttr").map(|v| v.parse().expect("--mttr")).unwrap_or(10.0),
            horizon_s: flags.get("horizon").map(|v| v.parse().expect("--horizon")).unwrap_or(120.0),
        };
        plan = plan.stochastic(&spec, n_instances);
    }
    plan
}

/// Replay under lifecycle faults: `replay` plus a fault plan and an
/// optional reactive autoscaler closing the loop.
fn cmd_faults(flags: &HashMap<String, String>) {
    use lmetric::cluster::QueueDepthAutoscaler;

    let exp = exp_from_flags(flags);
    let profile = ModelProfile::by_name(&exp.profile).expect("profile");
    let mut pol =
        policy::build(&exp.policy, exp.param, &profile, exp.chunk_budget).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let trace = cluster::build_scaled_trace(&exp);
    let cfg = cluster::cluster_config(&exp);
    let plan = plan_from_flags(flags, exp.instances);
    println!(
        "replaying {} ({} reqs) on {}×{} under {} with {} lifecycle events ...",
        exp.workload,
        exp.requests,
        exp.instances,
        exp.profile,
        pol.name(),
        plan.len()
    );
    let mut spec = RunSpec::open_loop(&cfg, &trace).with_faults(plan);
    if let Some(adm) = admission_from_flags(flags, &profile) {
        spec = spec.with_admission(adm);
    }
    if let Some(slo) = slo_from_flags(flags) {
        spec = spec.with_slo(slo);
    }
    if let Some(a) = flags.get("autoscale") {
        let p: Vec<f64> = a
            .split(':')
            .map(|v| v.parse().expect("--autoscale UP:DOWN:MIN:MAX"))
            .collect();
        if p.len() != 4 {
            eprintln!("--autoscale wants UP:DOWN:MIN:MAX (e.g. 8:2:2:16)");
            std::process::exit(2);
        }
        let tick_s: f64 = flags.get("tick").map(|v| v.parse().expect("--tick")).unwrap_or(1.0);
        let scaler = QueueDepthAutoscaler::new(p[0], p[1], p[2] as usize, p[3] as usize);
        spec = spec.with_autoscaler(Box::new(scaler), (tick_s * 1e6) as u64);
    }
    let m = cluster::run(spec, pol.as_mut());
    let row = ResultRow::from_metrics(&pol.name(), &m)
        .with("throughput_tok_s", m.output_throughput())
        .with("imbalance_s", m.imbalance_score());
    println!("{}", render_table(&format!("{} / faults", exp.workload), &[row]));
    let f = m.fault;
    println!(
        "lifecycle: {} crashes, {} drains ({} deadline violations), {} recovers, {} scale-ups",
        f.crashes, f.drains, f.drain_violations, f.recovers, f.scale_ups
    );
    println!(
        "displaced: {} killed, {} requeued, {} re-admitted, {} lost",
        f.killed, f.requeued, f.re_admitted, f.lost
    );
    if !m.cold_hit_samples.is_empty() {
        let mean = m.cold_hit_samples.iter().sum::<f64>() / m.cold_hit_samples.len() as f64;
        println!(
            "cold-start: {} samples, mean hit {:.1}% (run steady-state {:.1}%)",
            m.cold_hit_samples.len(),
            mean * 100.0,
            m.mean_hit_ratio() * 100.0
        );
    }
    print_overload_summary(&m);
}

fn cmd_compare(flags: &HashMap<String, String>) {
    let exp = exp_from_flags(flags);
    let profile = ModelProfile::by_name(&exp.profile).expect("profile");
    let trace = cluster::build_scaled_trace(&exp);
    let cfg = cluster::cluster_config(&exp);
    println!(
        "comparing all policies on {} ({} reqs, {:.1} req/s, {} instances)",
        exp.workload,
        trace.requests.len(),
        trace.mean_rps(),
        exp.instances
    );
    let mut rows = Vec::new();
    for name in policy::all_names() {
        let mut pol = policy::build_default(name, &profile, exp.chunk_budget).unwrap();
        let m = run_des(&cfg, &trace, pol.as_mut());
        rows.push(
            ResultRow::from_metrics(&pol.name(), &m)
                .with("throughput_tok_s", m.output_throughput()),
        );
    }
    println!("{}", render_table(&format!("{} / {}", exp.workload, exp.profile), &rows));
}

fn cmd_serve(flags: &HashMap<String, String>) {
    let n: usize = flags.get("instances").map(|v| v.parse().unwrap()).unwrap_or(2);
    let reqs: usize = flags.get("requests").map(|v| v.parse().unwrap()).unwrap_or(24);
    let policy_name = flags.get("policy").map(String::as_str).unwrap_or("lmetric");
    let time_scale: f64 = flags.get("time-scale").map(|v| v.parse().unwrap()).unwrap_or(20.0);

    // Live trace must fit the artifact model: vocab 1024, short prompts.
    let mut spec = WorkloadSpec::preset(Workload::ChatBot, reqs, 7);
    spec.vocab = 1023;
    spec.sys_prompt_median = 96.0;
    spec.user_span_median = 24.0;
    spec.output_median = 8.0;
    spec.output_sigma = 0.3;
    spec.max_input = 384;
    spec.mean_turns = 3.0;
    spec.turn_gap_s = 30.0;
    let trace = generate(&spec);

    let profile = ModelProfile::moe_30b();
    let mut pol = policy::build(policy_name, 0.7, &profile, 256).expect("policy");
    let queue_policy = flags.get("queue-policy").map(String::as_str).unwrap_or("fcfs");
    if let Err(e) = lmetric::engine::queue::build(queue_policy) {
        eprintln!("{e}");
        std::process::exit(2);
    }
    let cfg = LiveClusterConfig {
        n_instances: n,
        time_scale,
        queue_policy: queue_policy.to_string(),
        ..Default::default()
    };
    println!(
        "live serving {} requests on {} PJRT instances under {} (time ×{time_scale}) ...",
        trace.requests.len(),
        n,
        pol.name()
    );
    match run_live(&cfg, &trace, pol.as_mut()) {
        Ok(m) => {
            let row = ResultRow::from_metrics(&pol.name(), &m)
                .with("throughput_tok_s", m.output_throughput());
            println!("{}", render_table("live cluster (wall clock)", &[row]));
        }
        Err(e) => {
            eprintln!("live run failed: {e:#}\n(did you run `make artifacts`?)");
            std::process::exit(1);
        }
    }
}

fn cmd_gen_trace(flags: &HashMap<String, String>) {
    let workload = flags
        .get("workload")
        .and_then(|w| Workload::by_name(w))
        .unwrap_or(Workload::ChatBot);
    let reqs: usize = flags.get("requests").map(|v| v.parse().unwrap()).unwrap_or(4000);
    let seed: u64 = flags.get("seed").map(|v| v.parse().unwrap()).unwrap_or(42);
    let out = flags
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{}.jsonl", workload.name())));
    let trace = generate(&WorkloadSpec::preset(workload, reqs, seed));
    save_jsonl(&trace, &out).expect("write trace");
    println!("wrote {} requests to {}", trace.requests.len(), out.display());
}

fn cmd_trace_stats(flags: &HashMap<String, String>) {
    let trace = if let Some(file) = flags.get("file") {
        load_jsonl("file", Path::new(file)).expect("load trace")
    } else {
        let workload = flags
            .get("workload")
            .and_then(|w| Workload::by_name(w))
            .unwrap_or(Workload::ChatBot);
        let reqs: usize = flags.get("requests").map(|v| v.parse().unwrap()).unwrap_or(4000);
        generate(&WorkloadSpec::preset(workload, reqs, 42))
    };
    let (mean_in, mean_out) = trace.token_stats();
    println!("trace: {}", trace.name);
    println!("  requests:            {}", trace.requests.len());
    println!("  mean arrival rate:   {:.2} req/s", trace.mean_rps());
    println!("  mean input tokens:   {mean_in:.0}");
    println!("  mean output tokens:  {mean_out:.0}");
    println!(
        "  inf-KV$ hit rate:    {:.1}% (Fig 5 bottom row)",
        trace.infinite_cache_hit_rate() * 100.0
    );
    let classes: std::collections::BTreeSet<u32> =
        trace.requests.iter().map(|r| r.req.class_id).collect();
    println!("  request classes:     {}", classes.len());
}

fn cmd_calibrate(_flags: &HashMap<String, String>) {
    // Cross-check the analytic cost model's SHAPE against the real PJRT
    // transformer (or the sim backend in default builds): prefill cost is
    // ~linear in new tokens; decode cost grows mildly with batch. Absolute
    // scales differ (tiny CPU model vs H20).
    use lmetric::runtime::{ModelRuntime, Runtime};
    use std::time::Instant;
    let rt = match ModelRuntime::load(&lmetric::runtime::artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("calibrate needs artifacts: {e:#}");
            std::process::exit(1);
        }
    };
    println!("PJRT live-model step timings (CPU; shape-check for the cost model)");
    let kv = rt.zero_kv();
    for &chunk in rt.cfg.chunk_buckets.clone().iter() {
        let tokens: Vec<i32> = (0..chunk as i32).map(|t| 1 + t % 1000).collect();
        let t0 = Instant::now();
        let mut kv2 = kv.clone();
        let iters = 3;
        for _ in 0..iters {
            let (_, k) = rt.prefill_chunk(&kv2, &tokens, 0, 0, chunk).expect("prefill");
            kv2 = k;
        }
        let us = t0.elapsed().as_micros() as f64 / iters as f64;
        println!(
            "  prefill chunk={chunk:>4}: {:>10.0} µs  ({:.1} µs/token)",
            us,
            us / chunk as f64
        );
    }
    for bs in [1usize, 2, 4, 8] {
        let bs = bs.min(rt.cfg.slots);
        let mut kv2 = kv.clone();
        let mut tokens = vec![0i32; rt.cfg.slots];
        let mut lens = vec![0i32; rt.cfg.slots];
        // Give every decoding slot a real context first (one chunk of the
        // bucket closest to 64 tokens — manifests need not carry a 64).
        let ctx = rt.bucket_for(64).unwrap_or_else(|| rt.largest_bucket());
        for i in 0..bs {
            let span: Vec<i32> =
                (0..ctx as i32).map(|t| 1 + (i as i32 * 67 + t) % 1000).collect();
            let (_, k) = rt.prefill_chunk(&kv2, &span, i, 0, ctx).expect("prefill");
            kv2 = k;
            tokens[i] = 5;
            lens[i] = ctx as i32;
        }
        let t0 = Instant::now();
        let iters = 5;
        for _ in 0..iters {
            let (_, k) = rt.decode_step(&kv2, &tokens, &lens).expect("decode");
            kv2 = k;
            for l in lens.iter_mut().take(bs) {
                *l += 1; // the decoded token is now part of the context
            }
        }
        let us = t0.elapsed().as_micros() as f64 / iters as f64;
        println!("  decode  bs={bs}:        {us:>10.0} µs");
    }
    let p = ModelProfile::moe_30b();
    println!("\nanalytic profile {} (H20-class target):", p.name);
    for &chunk in &[16usize, 64, 256] {
        println!(
            "  prefill chunk={chunk:>4}: {:>10.0} µs (model)",
            p.step_us(chunk, chunk as f64 * 0.1, 0, 0)
        );
    }
    for bs in [1usize, 2, 4, 8] {
        println!(
            "  decode  bs={bs}:        {:>10.0} µs (model)",
            p.step_us(0, 0.0, bs, bs * 64)
        );
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: lmetric <command> [flags]

commands:
  replay       --workload W --policy P [--instances N --requests N --rate-scale F --param F --profile M --seed S --config FILE]
               [--queue-policy Q --admission A --admission-param F --slo-ttft S --slo-tpot S]
               [--fleet CLASS:N,... --n-models M]  (hardware classes: default h100 l40 a10)
  sessions     --kind chat|api|coding [--policy P --instances N --requests N --rate-scale F --seed S]
  open         --shape constant|ramp|diurnal|flash [--duration S --rate-scale F --instances N
               --requests N --seed S --policy P --admission A --admission-param F --slo-ttft S --slo-tpot S]
  faults       --workload W --policy P [--plan \"crash@T:I,recover@T:I,drain@T:I:D,scaleup@T[:warm]\"]
               [--crash-rate R --mttr S --horizon S --fault-seed N] [--autoscale UP:DOWN:MIN:MAX --tick S]
               [replay flags: --instances --requests --rate-scale --admission --slo-ttft ...]
  compare      --workload W [--instances N --requests N ...]
  serve        [--instances N --requests N --policy P --time-scale F]
  gen-trace    --workload W --requests N --out FILE
  trace-stats  [--workload W | --file F]
  calibrate

workloads:  chatbot coder agent toolagent hotspot
policies:   {:?}
queues:     {:?} (within-instance ordering, --queue-policy)
admission:  {:?}",
        policy::all_names(),
        lmetric::engine::queue::all_names(),
        cluster::all_admission_names()
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "replay" => cmd_replay(&flags),
        "sessions" => cmd_sessions(&flags),
        "open" => cmd_open(&flags),
        "faults" => cmd_faults(&flags),
        "compare" => cmd_compare(&flags),
        "serve" => cmd_serve(&flags),
        "gen-trace" => cmd_gen_trace(&flags),
        "trace-stats" => cmd_trace_stats(&flags),
        "calibrate" => cmd_calibrate(&flags),
        _ => usage(),
    }
}
