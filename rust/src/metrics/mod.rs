//! Run-level measurement: collects [`RequestRecord`]s plus the per-instance
//! timelines the paper's figures profile, and renders tables / CSV / JSON.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::core::RequestRecord;
use crate::router::GuardCounters;
use crate::util::json::Json;
use crate::util::stats::{cdf_points, stddev, Summary, Windowed};

/// A latency SLO: a request is *good* when its TTFT and (if it decoded)
/// its TPOT are both within budget. Goodput = good requests per second —
/// the metric that actually collapses under overload while raw
/// throughput keeps looking fine (see `cluster::overload`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// TTFT budget, seconds.
    pub ttft_s: f64,
    /// TPOT budget, seconds per output token.
    pub tpot_s: f64,
}

impl SloSpec {
    pub fn new(ttft_s: f64, tpot_s: f64) -> SloSpec {
        SloSpec { ttft_s, tpot_s }
    }

    /// Whether `r` met the SLO. Single-token requests have no decode
    /// phase, so only their TTFT counts (mirroring
    /// [`RunMetrics::tpots`]' filter).
    pub fn met_by(&self, r: &RequestRecord) -> bool {
        r.ttft_s() <= self.ttft_s && (r.output_len <= 1 || r.tpot_s() <= self.tpot_s)
    }
}

/// Admission-control outcome counters for one run. All-zero when the run
/// had no admission policy (`offered == admitted == 0` then means
/// "overload control not in play", and goodput denominators fall back to
/// completed records).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OverloadCounters {
    /// Arrivals presented to the admission policy.
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
    /// Sessions rejected whole at their first turn.
    pub shed_sessions: u64,
    /// Sheds that hit a session with previously admitted turns — the
    /// conversation-integrity violation session-aware shedding exists to
    /// prevent (0 for it, by construction).
    pub shed_mid_session: u64,
    /// Follow-up turns stranded by mid-session sheds (the reactive chain
    /// behind a shed turn can never release).
    pub orphaned_turns: u64,
}

/// Per-instance within-instance queue counters (the `engine::queue`
/// layer): admission wait times and the LTR starvation-promotion count.
/// Harvested per instance at the end of a DES run; empty for live /
/// concurrent runs (their engines run wall-clock and don't report).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueueCounters {
    /// Starvation promotions granted by the instance's queue policy
    /// (always 0 for fcfs/srpt — only `ltr` promotes).
    pub promotions: u64,
    /// Steps where a busy instance could not plan work (the livelock
    /// escape hatch; 0 under any legal config — asserted in tests).
    pub stalled_steps: u64,
    /// Sum / count / max of per-request admission waits (enqueue →
    /// running-batch admission), µs.
    pub wait_us_sum: u64,
    pub wait_samples: u64,
    pub wait_us_max: u64,
}

/// Model-multiplexing counters summed over the fleet (the
/// [`crate::engine::ModelSlots`] layer). All-zero for single-model runs:
/// model 0 ships warm everywhere and never swaps.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ModelCounters {
    /// Admissions that found their model cold (each paid one swap).
    pub cold_loads: u64,
    /// Warm models displaced to make room for a cold load.
    pub evictions: u64,
    /// Total µs of weight-swap time charged to engine steps.
    pub swap_us: u64,
}

/// Everything a cluster run produces.
#[derive(Debug)]
pub struct RunMetrics {
    pub records: Vec<RequestRecord>,
    /// Seconds spent on prefill per 10-s window, per instance (Figs 10/25).
    pub prefill_time: Vec<Windowed>,
    /// Running batch size sampled per second, per instance (Fig 28).
    pub batch_size: Vec<Windowed>,
    /// Router scheduling overhead per decision, µs.
    pub sched_overhead_us: Vec<f64>,
    /// Simulator |pred-actual|/actual TTFT error ratios (Fig 16), when a
    /// simulation-based policy ran.
    pub sim_error_ratio: Vec<f64>,
    /// Virtual (or wall) duration of the run, µs.
    pub duration_us: u64,
    /// Engine steps executed across all instances (DES runs; the bench
    /// harness derives steps/s from it).
    pub total_steps: u64,
    /// Fused KV$ admission walks across all instances. The engine walks
    /// its radix tree exactly once per admission, so this equals the
    /// number of admitted requests — the harness asserts it.
    pub admit_radix_walks: u64,
    /// Failure-condition guard counters of the run's policy (all-zero
    /// for unguarded policies). Populated by both the DES and the live
    /// cluster at the end of a run from
    /// [`Policy::guard_counters`](crate::router::Policy::guard_counters),
    /// as THIS run's delta (policies accumulate over their lifetime).
    pub guard: GuardCounters,
    /// Admission-control counters (all-zero when no admission policy ran).
    pub overload: OverloadCounters,
    /// Fleet-lifecycle counters (all-zero when the run had no
    /// [`FaultPlan`](crate::cluster::FaultPlan) and no autoscaler).
    pub fault: crate::cluster::FaultCounters,
    /// Prompt KV$ hit ratios of the first completions on an instance
    /// after it (re)joined cold — the cache-warmup hit curve a scale-up
    /// pays (sampled while `fault.cold_samples` counts them).
    pub cold_hit_samples: Vec<f64>,
    /// Snapshot age per decision, in factory commits the router's pinned
    /// view was stale by when the decision merged (0 for every decision in
    /// a serial run; bounded by the staleness budget in
    /// `cluster::run_concurrent`). Empty for serial runs.
    pub snapshot_age: Vec<f64>,
    /// Wall seconds spent in the concurrent routing phase (fills + policy
    /// scoring across all workers); 0 for serial runs. Decision throughput
    /// = decisions / this.
    pub route_wall_s: f64,
    /// Router workers that scored decisions (1 for serial runs).
    pub routers: usize,
    /// Name of the admission policy that ran, if any.
    pub admission_name: Option<String>,
    /// The SLO this run was evaluated against, if any (set by
    /// [`crate::cluster::RunSpec::with_slo`]; goodput methods take an
    /// explicit spec too so post-hoc evaluation works).
    pub slo: Option<SloSpec>,
    /// Per-instance within-instance queue counters, one entry per
    /// instance slot the run ended with (scale-ups grow it past the
    /// starting fleet). Empty for live/concurrent runs.
    pub queue: Vec<QueueCounters>,
    /// Model-multiplexing counters summed over the fleet (all-zero for
    /// single-model runs).
    pub models: ModelCounters,
}

impl RunMetrics {
    pub fn new(n_instances: usize) -> Self {
        RunMetrics {
            records: Vec::new(),
            prefill_time: (0..n_instances).map(|_| Windowed::new(10_000_000)).collect(),
            batch_size: (0..n_instances).map(|_| Windowed::new(1_000_000)).collect(),
            sched_overhead_us: Vec::new(),
            sim_error_ratio: Vec::new(),
            duration_us: 0,
            total_steps: 0,
            admit_radix_walks: 0,
            guard: GuardCounters::default(),
            overload: OverloadCounters::default(),
            fault: crate::cluster::FaultCounters::default(),
            cold_hit_samples: Vec::new(),
            snapshot_age: Vec::new(),
            route_wall_s: 0.0,
            routers: 1,
            admission_name: None,
            slo: None,
            queue: Vec::new(),
            models: ModelCounters::default(),
        }
    }

    /// Total starvation promotions across all instances' queue policies
    /// (0 unless an `ltr` engine queue promoted someone).
    pub fn total_promotions(&self) -> u64 {
        self.queue.iter().map(|q| q.promotions).sum()
    }

    /// Total stalled (unplannable-while-busy) steps across instances —
    /// 0 under any legal engine config.
    pub fn total_stalled_steps(&self) -> u64 {
        self.queue.iter().map(|q| q.stalled_steps).sum()
    }

    /// Mean admission wait (enqueue → running-batch admission) across
    /// all instances, in seconds; 0.0 when nothing was sampled.
    pub fn mean_queue_wait_s(&self) -> f64 {
        let n: u64 = self.queue.iter().map(|q| q.wait_samples).sum();
        if n == 0 {
            return 0.0;
        }
        let sum: u64 = self.queue.iter().map(|q| q.wait_us_sum).sum();
        sum as f64 / n as f64 / 1e6
    }

    /// Worst single admission wait across the fleet, seconds.
    pub fn max_queue_wait_s(&self) -> f64 {
        self.queue.iter().map(|q| q.wait_us_max).max().unwrap_or(0) as f64 / 1e6
    }

    /// Distribution of snapshot ages (commits of staleness per decision);
    /// `n == 0` for serial runs.
    pub fn snapshot_age_summary(&self) -> Summary {
        Summary::of(&self.snapshot_age)
    }

    /// Routing decisions per wall second of the routing phase (the
    /// router-scale figure's y-axis). 0 when the run didn't measure a
    /// routing phase (serial runs leave `route_wall_s` at 0).
    pub fn decision_throughput(&self) -> f64 {
        if self.route_wall_s <= 0.0 {
            return 0.0;
        }
        self.sched_overhead_us.len() as f64 / self.route_wall_s
    }

    /// Completed requests that met `slo`.
    pub fn slo_good(&self, slo: SloSpec) -> usize {
        self.records.iter().filter(|r| slo.met_by(r)).count()
    }

    /// Fraction of *completed* requests inside the SLO.
    pub fn slo_attainment(&self, slo: SloSpec) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.slo_good(slo) as f64 / self.records.len() as f64
    }

    /// Goodput ratio: SLO-good completions over *offered* load. Shed
    /// requests count against goodput — an admission policy cannot look
    /// better by rejecting everything. Runs without admission control
    /// (offered == 0) fall back to completed records as the denominator,
    /// making this identical to [`RunMetrics::slo_attainment`] there.
    pub fn goodput_ratio(&self, slo: SloSpec) -> f64 {
        let denom = if self.overload.offered > 0 {
            self.overload.offered as usize
        } else {
            self.records.len()
        };
        if denom == 0 {
            return 0.0;
        }
        self.slo_good(slo) as f64 / denom as f64
    }

    /// Goodput in SLO-good requests per second of run time.
    pub fn goodput_rps(&self, slo: SloSpec) -> f64 {
        if self.duration_us == 0 {
            return 0.0;
        }
        self.slo_good(slo) as f64 / (self.duration_us as f64 / 1e6)
    }

    pub fn ttfts(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.ttft_s()).collect()
    }

    /// TPOTs of requests that actually decoded (>1 output token).
    pub fn tpots(&self) -> Vec<f64> {
        self.records
            .iter()
            .filter(|r| r.output_len > 1)
            .map(|r| r.tpot_s())
            .collect()
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttfts())
    }

    pub fn tpot_summary(&self) -> Summary {
        Summary::of(&self.tpots())
    }

    /// Mean prompt KV$ hit ratio over all requests.
    pub fn mean_hit_ratio(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.hit_ratio()).sum::<f64>() / self.records.len() as f64
    }

    /// Hit ratio per 1-minute window (Figs 8/9/24 timelines).
    pub fn hit_ratio_timeline(&self) -> Windowed {
        let mut w = Windowed::new(60_000_000);
        for r in &self.records {
            w.add(r.arrival_us, r.hit_ratio());
        }
        w
    }

    /// Output token throughput in tokens/s.
    pub fn output_throughput(&self) -> f64 {
        if self.duration_us == 0 {
            return 0.0;
        }
        let toks: u64 = self.records.iter().map(|r| r.output_len as u64).sum();
        toks as f64 / (self.duration_us as f64 / 1e6)
    }

    /// Drop records from the cold-start transient: requests arriving in
    /// the first `frac` of the run (standard steady-state methodology —
    /// the paper replays hour-long traces where warm-up is negligible;
    /// our shorter replays must discard it explicitly).
    pub fn discard_warmup(&mut self, frac: f64) {
        let cutoff = (self.duration_us as f64 * frac) as u64;
        self.records.retain(|r| r.arrival_us >= cutoff);
    }

    /// Imbalance profile (§4.3 / Fig 10 methodology): pick the two
    /// instances with the highest stddev of per-window prefill time and
    /// return (idx_a, series_a, idx_b, series_b).
    pub fn top2_imbalanced_instances(&self) -> Option<(usize, Vec<f64>, usize, Vec<f64>)> {
        if self.prefill_time.len() < 2 {
            return None;
        }
        let mut ranked: Vec<(usize, f64)> = self
            .prefill_time
            .iter()
            .enumerate()
            .map(|(i, w)| (i, stddev(w.sums())))
            .collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let (a, b) = (ranked[0].0, ranked[1].0);
        Some((
            a,
            self.prefill_time[a].sums().to_vec(),
            b,
            self.prefill_time[b].sums().to_vec(),
        ))
    }

    /// Mean absolute per-window prefill-time gap between the two most
    /// divergent instances — the scalar imbalance measure behind Fig 10's
    /// "3.57s vs 2.17s" comparison.
    pub fn imbalance_score(&self) -> f64 {
        match self.top2_imbalanced_instances() {
            None => 0.0,
            Some((_, a, _, b)) => {
                let n = a.len().min(b.len());
                if n == 0 {
                    return 0.0;
                }
                (0..n).map(|i| (a[i] - b[i]).abs()).sum::<f64>() / n as f64
            }
        }
    }
}

/// Turn indices at or above this are folded into the last bucket of the
/// per-turn prefix-hit curve (long agent loops get a "deep turns" tail
/// instead of an unbounded vector).
pub const TURN_CURVE_CAP: usize = 16;

/// Per-session aggregates of a closed-loop run
/// ([`crate::cluster::run_session_des`]): joins the flat
/// [`RequestRecord`]s back to their (session, turn) positions.
#[derive(Debug, Clone)]
pub struct SessionMetrics {
    /// Sessions with at least one completed turn / completed turns seen.
    pub sessions: usize,
    pub turns: usize,
    /// Consecutive-turn pairs routed to the same instance, out of all
    /// consecutive pairs with both records present. The affinity a sticky
    /// router gets by construction — and the one an indicator router must
    /// earn through its KV$-awareness.
    pub affinity_hits: usize,
    pub affinity_total: usize,
    /// Mean prompt KV$ hit ratio by turn index (bucket `TURN_CURVE_CAP-1`
    /// aggregates all deeper turns), with per-bucket sample counts.
    pub turn_hit_curve: Vec<f64>,
    pub turn_hit_counts: Vec<usize>,
    pub turn_ttft: Summary,
    pub turn_tpot: Summary,
    /// Distribution of per-session *mean* TTFT (one sample per session).
    pub session_mean_ttft: Summary,
    /// Per-session wall span, first arrival → last completion, seconds.
    pub session_span_s: Summary,
}

impl SessionMetrics {
    /// Join `m.records` to `st`'s sessions. Records absent from `m`
    /// (warm-up-discarded or still in flight) are skipped; affinity pairs
    /// require both sides present.
    pub fn collect(m: &RunMetrics, st: &crate::trace::SessionTrace) -> SessionMetrics {
        let rec_of: BTreeMap<u64, &RequestRecord> = m.records.iter().map(|r| (r.id, r)).collect();
        let mut out = SessionMetrics {
            sessions: 0,
            turns: 0,
            affinity_hits: 0,
            affinity_total: 0,
            turn_hit_curve: vec![0.0; TURN_CURVE_CAP],
            turn_hit_counts: vec![0; TURN_CURVE_CAP],
            turn_ttft: Summary::of(&[]),
            turn_tpot: Summary::of(&[]),
            session_mean_ttft: Summary::of(&[]),
            session_span_s: Summary::of(&[]),
        };
        let mut ttfts: Vec<f64> = Vec::new();
        let mut tpots: Vec<f64> = Vec::new();
        let mut session_means: Vec<f64> = Vec::new();
        let mut spans: Vec<f64> = Vec::new();
        for s in &st.sessions {
            let recs: Vec<(usize, &RequestRecord)> = s
                .turns
                .iter()
                .enumerate()
                .filter_map(|(ti, t)| rec_of.get(&t.req.id).map(|r| (ti, *r)))
                .collect();
            if recs.is_empty() {
                continue;
            }
            out.sessions += 1;
            let mut sess_ttft_sum = 0.0;
            for &(ti, r) in &recs {
                out.turns += 1;
                let bucket = ti.min(TURN_CURVE_CAP - 1);
                out.turn_hit_curve[bucket] += r.hit_ratio();
                out.turn_hit_counts[bucket] += 1;
                ttfts.push(r.ttft_s());
                sess_ttft_sum += r.ttft_s();
                if r.output_len > 1 {
                    tpots.push(r.tpot_s());
                }
            }
            for w in recs.windows(2) {
                if w[1].0 == w[0].0 + 1 {
                    out.affinity_total += 1;
                    if w[1].1.instance == w[0].1.instance {
                        out.affinity_hits += 1;
                    }
                }
            }
            session_means.push(sess_ttft_sum / recs.len() as f64);
            let first_arrival = recs.iter().map(|(_, r)| r.arrival_us).min().unwrap();
            let last_done = recs.iter().map(|(_, r)| r.completion_us).max().unwrap();
            spans.push((last_done - first_arrival) as f64 / 1e6);
        }
        for i in 0..TURN_CURVE_CAP {
            out.turn_hit_curve[i] = if out.turn_hit_counts[i] == 0 {
                f64::NAN
            } else {
                out.turn_hit_curve[i] / out.turn_hit_counts[i] as f64
            };
        }
        out.turn_ttft = Summary::of(&ttfts);
        out.turn_tpot = Summary::of(&tpots);
        out.session_mean_ttft = Summary::of(&session_means);
        out.session_span_s = Summary::of(&spans);
        out
    }

    /// Fraction of consecutive turns kept on the previous turn's
    /// instance (NaN when the run had no multi-turn pairs).
    pub fn affinity_ratio(&self) -> f64 {
        if self.affinity_total == 0 {
            f64::NAN
        } else {
            self.affinity_hits as f64 / self.affinity_total as f64
        }
    }

    /// Mean hit ratio of turn 0 (the cold entry point of every session).
    pub fn turn0_hit(&self) -> f64 {
        self.turn_hit_curve[0]
    }

    /// Mean hit ratio over all turns past the first — how much the
    /// growing shared context pays once a session is warm.
    pub fn late_turn_hit(&self) -> f64 {
        let (mut sum, mut n) = (0.0, 0usize);
        for i in 1..TURN_CURVE_CAP {
            if self.turn_hit_counts[i] > 0 {
                sum += self.turn_hit_curve[i] * self.turn_hit_counts[i] as f64;
                n += self.turn_hit_counts[i];
            }
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }
}

/// One labelled result row (e.g. one policy on one trace).
#[derive(Debug, Clone)]
pub struct ResultRow {
    pub label: String,
    pub ttft: Summary,
    pub tpot: Summary,
    pub hit_ratio: f64,
    pub extra: BTreeMap<String, f64>,
}

impl ResultRow {
    pub fn from_metrics(label: &str, m: &RunMetrics) -> Self {
        ResultRow {
            label: label.to_string(),
            ttft: m.ttft_summary(),
            tpot: m.tpot_summary(),
            hit_ratio: m.mean_hit_ratio(),
            extra: BTreeMap::new(),
        }
    }

    pub fn with(mut self, key: &str, v: f64) -> Self {
        self.extra.insert(key.to_string(), v);
        self
    }
}

/// Render rows as an aligned text table (the benches' stdout format).
pub fn render_table(title: &str, rows: &[ResultRow]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n## {title}\n"));
    out.push_str(&format!(
        "{:<28} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7}\n",
        "policy/config",
        "TTFT-mean",
        "TTFT-p50",
        "TTFT-p99",
        "TPOT-mean",
        "TPOT-p50",
        "TPOT-p99",
        "KV$hit"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<28} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>6.1}%\n",
            r.label,
            fmt_s(r.ttft.mean),
            fmt_s(r.ttft.p50),
            fmt_s(r.ttft.p99),
            fmt_s(r.tpot.mean),
            fmt_s(r.tpot.p50),
            fmt_s(r.tpot.p99),
            r.hit_ratio * 100.0
        ));
        if !r.extra.is_empty() {
            let kv: Vec<String> = r.extra.iter().map(|(k, v)| format!("{k}={v:.4}")).collect();
            out.push_str(&format!("{:<28} {}\n", "", kv.join("  ")));
        }
    }
    out
}

/// Seconds with adaptive precision (ms below 1 s).
pub fn fmt_s(v: f64) -> String {
    if v.is_nan() {
        "-".into()
    } else if v < 1.0 {
        format!("{:.1}ms", v * 1e3)
    } else {
        format!("{v:.2}s")
    }
}

/// Persist rows (plus optional CDFs) under results/<name>.json.
pub fn save_results(
    name: &str,
    rows: &[ResultRow],
    cdfs: &[(String, Vec<f64>)],
) -> std::io::Result<std::path::PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let mut obj = vec![(
        "rows".to_string(),
        Json::Arr(
            rows.iter()
                .map(|r| {
                    let mut o: Vec<(String, Json)> = vec![
                        ("label".into(), Json::Str(r.label.clone())),
                        ("ttft_mean".into(), Json::Num(r.ttft.mean)),
                        ("ttft_p50".into(), Json::Num(r.ttft.p50)),
                        ("ttft_p95".into(), Json::Num(r.ttft.p95)),
                        ("ttft_p99".into(), Json::Num(r.ttft.p99)),
                        ("tpot_mean".into(), Json::Num(r.tpot.mean)),
                        ("tpot_p50".into(), Json::Num(r.tpot.p50)),
                        ("tpot_p99".into(), Json::Num(r.tpot.p99)),
                        ("hit_ratio".into(), Json::Num(r.hit_ratio)),
                    ];
                    for (k, v) in &r.extra {
                        o.push((k.clone(), Json::Num(*v)));
                    }
                    Json::Obj(o.into_iter().collect())
                })
                .collect(),
        ),
    )];
    for (label, values) in cdfs {
        let pts = cdf_points(values, 200);
        obj.push((
            format!("cdf_{label}"),
            Json::Arr(
                pts.iter()
                    .map(|(x, p)| Json::Arr(vec![Json::Num(*x), Json::Num(*p)]))
                    .collect(),
            ),
        ));
    }
    let path = dir.join(format!("{name}.json"));
    let mut f = std::fs::File::create(&path)?;
    f.write_all(Json::Obj(obj.into_iter().collect()).to_string().as_bytes())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::RequestRecord;

    fn mk_record(id: u64, arrival: u64, first: u64, done: u64, out: u32) -> RequestRecord {
        RequestRecord {
            id,
            class_id: 0,
            instance: (id % 2) as usize,
            arrival_us: arrival,
            first_token_us: first,
            completion_us: done,
            input_len: 100,
            output_len: out,
            cached_tokens: 50,
        }
    }

    #[test]
    fn summaries() {
        let mut m = RunMetrics::new(2);
        m.records.push(mk_record(1, 0, 100_000, 1_100_000, 11));
        m.records.push(mk_record(2, 0, 300_000, 2_300_000, 21));
        m.duration_us = 2_300_000;
        let t = m.ttft_summary();
        assert_eq!(t.n, 2);
        assert!((t.mean - 0.2).abs() < 1e-9);
        assert!((m.tpot_summary().mean - 0.1).abs() < 1e-9);
        assert!((m.mean_hit_ratio() - 0.5).abs() < 1e-9);
        assert!(m.output_throughput() > 0.0);
    }

    #[test]
    fn slo_and_goodput_accounting() {
        let mut m = RunMetrics::new(1);
        // TTFT 0.1 s, TPOT 0.1 s -> good under (0.2, 0.2).
        m.records.push(mk_record(1, 0, 100_000, 1_100_000, 11));
        // TTFT 0.3 s -> blown.
        m.records.push(mk_record(2, 0, 300_000, 2_300_000, 21));
        // Single-token: only TTFT counts (0.1 s -> good).
        m.records.push(mk_record(3, 0, 100_000, 100_000, 1));
        m.duration_us = 2_000_000;
        let slo = SloSpec::new(0.2, 0.2);
        assert!(slo.met_by(&m.records[0]));
        assert!(!slo.met_by(&m.records[1]));
        assert!(slo.met_by(&m.records[2]));
        assert_eq!(m.slo_good(slo), 2);
        assert!((m.slo_attainment(slo) - 2.0 / 3.0).abs() < 1e-12);
        // No admission policy: goodput denominates over completions.
        assert!((m.goodput_ratio(slo) - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.goodput_rps(slo) - 1.0).abs() < 1e-12);
        // With admission counters, shed requests drag goodput down.
        m.overload.offered = 8;
        m.overload.admitted = 3;
        m.overload.shed = 5;
        assert!((m.goodput_ratio(slo) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn single_token_requests_excluded_from_tpot() {
        let mut m = RunMetrics::new(1);
        m.records.push(mk_record(1, 0, 10, 10, 1));
        assert_eq!(m.tpot_summary().n, 0);
    }

    #[test]
    fn imbalance_score_detects_divergence() {
        let mut m = RunMetrics::new(3);
        for w in 0..10 {
            m.prefill_time[0].add(w * 10_000_000, 5.0);
            m.prefill_time[1].add(w * 10_000_000, 1.0);
            m.prefill_time[2].add(w * 10_000_000, 3.0);
        }
        // Balanced run: all equal.
        let mut b = RunMetrics::new(3);
        for w in 0..10 {
            for i in 0..3 {
                b.prefill_time[i].add(w * 10_000_000, 3.0);
            }
        }
        assert!(m.imbalance_score() > b.imbalance_score());
    }

    #[test]
    fn session_metrics_affinity_and_curve() {
        use crate::trace::{generate_sessions, SessionKind, SessionSpec};
        let mut spec = SessionSpec::preset(SessionKind::ApiCall, 60, 5);
        spec.mean_turns = 3.0;
        let st = generate_sessions(&spec);
        let mut m = RunMetrics::new(2);
        // Fabricate one record per turn: even-indexed sessions ping-pong
        // between instances (zero affinity), odd-indexed stay put (full).
        let mut expect_hits = 0usize;
        let mut expect_total = 0usize;
        for (si, s) in st.sessions.iter().enumerate() {
            if si == 0 {
                continue; // dropped session: must be skipped, not crash
            }
            for (ti, t) in s.turns.iter().enumerate() {
                let instance = if si % 2 == 0 { ti % 2 } else { 0 };
                let arrival = (si * 1000 + ti * 10) as u64 * 1000;
                m.records.push(RequestRecord {
                    id: t.req.id,
                    class_id: t.req.class_id,
                    instance,
                    arrival_us: arrival,
                    first_token_us: arrival + 50_000,
                    completion_us: arrival + 250_000,
                    input_len: t.req.input_len() as u32,
                    output_len: t.req.output_len.max(2),
                    cached_tokens: (t.req.input_len() / 2) as u32,
                });
            }
            expect_total += s.turns.len().saturating_sub(1);
            if si % 2 != 0 {
                expect_hits += s.turns.len().saturating_sub(1);
            }
        }
        let sm = SessionMetrics::collect(&m, &st);
        assert_eq!(sm.sessions, st.sessions.len() - 1);
        assert_eq!(sm.turns, m.records.len());
        assert_eq!(sm.affinity_total, expect_total);
        assert_eq!(sm.affinity_hits, expect_hits);
        assert!((sm.affinity_ratio() - expect_hits as f64 / expect_total as f64).abs() < 1e-12);
        // Every record was fabricated with a 50% prompt-hit ratio (give or
        // take integer division), so every populated curve bucket sits
        // near 0.5 and turn 0 is populated.
        assert!(sm.turn_hit_counts[0] > 0);
        assert!((sm.turn0_hit() - 0.5).abs() < 0.05);
        assert!((sm.turn_ttft.mean - 0.05).abs() < 1e-9);
        assert!(sm.session_span_s.n == sm.sessions);
    }

    #[test]
    fn queue_counter_aggregates() {
        let mut m = RunMetrics::new(2);
        assert_eq!(m.total_promotions(), 0);
        assert_eq!(m.mean_queue_wait_s(), 0.0);
        m.queue.push(QueueCounters {
            promotions: 3,
            stalled_steps: 0,
            wait_us_sum: 1_000_000,
            wait_samples: 2,
            wait_us_max: 900_000,
        });
        m.queue.push(QueueCounters {
            promotions: 1,
            stalled_steps: 0,
            wait_us_sum: 2_000_000,
            wait_samples: 2,
            wait_us_max: 1_500_000,
        });
        assert_eq!(m.total_promotions(), 4);
        assert_eq!(m.total_stalled_steps(), 0);
        assert!((m.mean_queue_wait_s() - 0.75).abs() < 1e-12);
        assert!((m.max_queue_wait_s() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn table_renders() {
        let m = RunMetrics::new(1);
        let row = ResultRow::from_metrics("x", &m).with("score", 1.0);
        let t = render_table("t", &[row]);
        assert!(t.contains("x"));
        assert!(t.contains("score=1.0000"));
    }

    #[test]
    fn save_results_writes_json() {
        let m = RunMetrics::new(1);
        let rows = vec![ResultRow::from_metrics("p", &m)];
        let path = save_results("_test_metrics", &rows, &[("ttft".into(), vec![1.0, 2.0])])
            .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert!(v.get("rows").is_some());
        assert!(v.get("cdf_ttft").is_some());
        std::fs::remove_file(path).ok();
    }
}
