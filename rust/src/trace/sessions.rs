//! Closed-loop *session* workloads: multi-turn conversations and agent
//! loops whose turn `k+1` depends on turn `k`'s response.
//!
//! The single-shot generators in [`super::synth`] emit sessions too, but
//! with *open-loop* (pre-scheduled) arrivals: every turn's timestamp is
//! fixed at generation time, so a slow cluster receives future turns of a
//! conversation before it has answered the previous one. Real agentic
//! traffic is closed-loop: the client only sends turn `k+1` after it has
//! *seen* turn `k`'s completion, then thinks (a human) or executes a tool
//! call (an agent) for a while. This module generates that structure:
//!
//! * a [`SessionTrace`] is a set of sessions, each a chain of
//!   [`SessionTurn`]s where turn `k+1`'s prompt = turn `k`'s full
//!   (prompt + assistant reply) context + the new user/tool span;
//! * only the *first* turn of a session carries a wall-clock arrival
//!   (sessions arrive Poisson); every later turn carries a pre-sampled
//!   `think_us` and is **released by the DES at the previous turn's
//!   completion + think time** ([`crate::cluster::run_session_des`]);
//! * all randomness is drawn at generation time, so a closed-loop replay
//!   is exactly as deterministic as an open-loop one.
//!
//! Three session archetypes cover the paper's claimed deployment mix
//! ("chatbots, API calls, and coding agents"): human-paced chat,
//! short tool-latency API call chains, and long coding-agent loops with
//! chunky tool results and machine-speed turn gaps.
//!
//! **Turn-growth recurrence.** Prompt/context lengths follow
//!
//! ```text
//! ctx_0      = sys_len
//! prompt_k   = min(ctx_k + user_k, max_input)   // truncation guard
//! full_k     = prompt_k + reply_k               // cached at completion
//! ctx_{k+1}  = full_k
//! ```
//!
//! exposed verbatim as [`turn_growth`] so tests (and the Python mirror
//! suite, `python/tests/test_session_growth.py`, which fuzzes the
//! recurrence against a token-list simulation in the container that has
//! no Rust toolchain) can check the generator's arithmetic out-of-band.

use std::collections::HashMap;
use std::sync::Arc;

use crate::core::Request;
use crate::tokenizer::{block_hashes, span};
use crate::util::rng::Zipf;
use crate::util::Rng;

use super::{clamp_len, Trace, TraceRequest};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionKind {
    /// Human conversations: shared system prompts, ~20 s think times,
    /// long assistant replies.
    Chat,
    /// API-call chains: short prompts, sub-second tool latencies, short
    /// chains (often one call plus one follow-up).
    ApiCall,
    /// Coding agents: long per-repo context, chunky tool-result spans,
    /// many machine-paced turns, short replies.
    CodingAgent,
}

impl SessionKind {
    pub fn by_name(name: &str) -> Option<SessionKind> {
        Some(match name {
            "chat" => SessionKind::Chat,
            "api" => SessionKind::ApiCall,
            "coding" => SessionKind::CodingAgent,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SessionKind::Chat => "chat",
            SessionKind::ApiCall => "api",
            SessionKind::CodingAgent => "coding",
        }
    }
}

/// Distribution parameters of one session workload.
#[derive(Debug, Clone)]
pub struct SessionSpec {
    pub kind: SessionKind,
    /// Total turns (= requests) to generate across all sessions.
    pub n_requests: usize,
    pub seed: u64,
    pub vocab: u32,
    /// Request classes (apps/users with shared system prompts) and the
    /// Zipf exponent of their popularity.
    pub n_classes: usize,
    pub class_skew: f64,
    /// Median system-prompt / per-turn user-span / reply lengths.
    pub sys_prompt_median: f64,
    pub user_span_median: f64,
    pub output_median: f64,
    pub output_sigma: f64,
    /// Turns per session: geometric with this mean, capped at `max_turns`.
    pub mean_turns: f64,
    pub max_turns: usize,
    /// Mean think time (human) / tool latency (agent) between a turn's
    /// completion and the next turn's arrival, seconds. Exponentially
    /// distributed, sampled per turn at generation time.
    pub think_time_s: f64,
    /// Session arrival rate, sessions/s (Poisson; pre-scaling).
    pub session_rate: f64,
    /// Max prompt length (long-context truncation guard).
    pub max_input: usize,
}

impl SessionSpec {
    pub fn preset(kind: SessionKind, n_requests: usize, seed: u64) -> SessionSpec {
        let base = SessionSpec {
            kind,
            n_requests,
            seed,
            vocab: 50_000,
            n_classes: 12,
            class_skew: 1.1,
            sys_prompt_median: 400.0,
            user_span_median: 60.0,
            output_median: 250.0,
            output_sigma: 0.7,
            mean_turns: 5.0,
            max_turns: 40,
            think_time_s: 20.0,
            session_rate: 2.0,
            max_input: 16_384,
        };
        match kind {
            SessionKind::Chat => base,
            SessionKind::ApiCall => SessionSpec {
                n_classes: 30,
                class_skew: 1.2,
                sys_prompt_median: 150.0,
                user_span_median: 80.0,
                output_median: 60.0,
                output_sigma: 0.6,
                mean_turns: 2.0,
                max_turns: 12,
                think_time_s: 0.5,
                session_rate: 6.0,
                ..base
            },
            SessionKind::CodingAgent => SessionSpec {
                n_classes: 8,
                class_skew: 0.9,
                sys_prompt_median: 2500.0,
                user_span_median: 300.0, // tool results are chunky
                output_median: 120.0,
                output_sigma: 0.6,
                mean_turns: 10.0,
                max_turns: 40,
                think_time_s: 1.0,
                session_rate: 1.0,
                ..base
            },
        }
    }
}

/// One turn of a session. `req.arrival_us` is the session start for turn
/// 0 and a placeholder (0) for later turns — the reactive DES stamps it
/// at release time. `think_us` is the sampled gap between the *previous*
/// turn's completion and this turn's arrival (0 for turn 0).
#[derive(Debug, Clone)]
pub struct SessionTurn {
    pub req: Request,
    pub full_hashes: Arc<[u64]>,
    pub think_us: u64,
}

/// One session: a causal chain of turns sharing a growing context.
#[derive(Debug, Clone)]
pub struct Session {
    pub sid: u64,
    pub class_id: u32,
    pub start_us: u64,
    pub turns: Vec<SessionTurn>,
}

/// A closed-loop trace: sessions ordered by start time; request ids are
/// dense (0..n_turns) in (session, turn) order.
#[derive(Debug, Clone)]
pub struct SessionTrace {
    pub name: String,
    pub sessions: Vec<Session>,
}

impl SessionTrace {
    /// Total turns (= requests) in the trace.
    pub fn n_turns(&self) -> usize {
        self.sessions.iter().map(|s| s.turns.len()).sum()
    }

    /// Map request id → (session index, turn index) for joining
    /// [`crate::core::RequestRecord`]s back to their session position.
    pub fn turn_index(&self) -> HashMap<u64, (usize, usize)> {
        let mut map = HashMap::with_capacity(self.n_turns());
        for (si, s) in self.sessions.iter().enumerate() {
            for (ti, t) in s.turns.iter().enumerate() {
                map.insert(t.req.id, (si, ti));
            }
        }
        map
    }

    /// The open-loop (fixed-schedule) view of this trace: every turn's
    /// arrival is stamped as the previous turn's *arrival* + think time —
    /// i.e. service time is approximated away. Used for capacity probing
    /// (the rate a fast cluster would see) and as the exact equivalent of
    /// a single-turn session trace; a closed-loop replay of multi-turn
    /// sessions goes through [`crate::cluster::run_session_des`] instead.
    pub fn flatten(&self) -> Trace {
        let mut requests: Vec<TraceRequest> = Vec::with_capacity(self.n_turns());
        for s in &self.sessions {
            let mut t_us = s.start_us;
            for (ti, turn) in s.turns.iter().enumerate() {
                if ti > 0 {
                    t_us += turn.think_us;
                }
                let mut req = turn.req.clone();
                req.arrival_us = t_us;
                requests.push(TraceRequest {
                    req,
                    full_hashes: turn.full_hashes.clone(),
                });
            }
        }
        requests.sort_by_key(|r| (r.req.arrival_us, r.req.id));
        Trace {
            name: self.name.clone(),
            requests,
        }
    }
}

/// The module-doc turn-growth recurrence in closed form: per turn,
/// `(prompt_len, full_len)` given the system-prompt length and the
/// per-turn user/reply span lengths. The generator's token vectors obey
/// this exactly (asserted in tests); the Python mirror suite fuzzes it
/// against an independent token-list simulation.
pub fn turn_growth(
    sys_len: usize,
    user_lens: &[usize],
    reply_lens: &[usize],
    max_input: usize,
) -> Vec<(usize, usize)> {
    let mut ctx = sys_len;
    user_lens
        .iter()
        .zip(reply_lens)
        .map(|(&u, &r)| {
            let prompt = (ctx + u).min(max_input);
            let full = prompt + r;
            ctx = full;
            (prompt, full)
        })
        .collect()
}

/// Build one session's turn chain: exactly the per-session rng draws
/// [`generate_sessions`] has always made, in the same order (system-prompt
/// length → turn count → per-turn user/reply/think samples), extracted so
/// the open-arrival engine ([`super::open`]) can grow archetype-mix
/// sessions from the identical machinery. `budget` caps how many turns are
/// materialized (the caller's global request budget); capped turns draw
/// nothing, exactly like the old in-loop break, so every pre-existing
/// trace replays byte-for-byte.
pub(crate) fn build_turn_chain(
    spec: &SessionSpec,
    rng: &mut Rng,
    class: u32,
    sid: u64,
    start_us: u64,
    budget: usize,
) -> Vec<SessionTurn> {
    let sys_len = clamp_len(
        rng.lognormal(spec.sys_prompt_median, 0.3),
        32,
        spec.max_input / 2,
    );
    let p_stop = 1.0 / spec.mean_turns.max(1.0);
    let mut n_turns = 1usize;
    while !rng.gen_bool(p_stop) && n_turns < spec.max_turns {
        n_turns += 1;
    }

    let mut prompt: Vec<u32> = span(class, 0, sys_len, spec.vocab);
    let mut turns: Vec<SessionTurn> = Vec::with_capacity(n_turns.min(budget));
    for turn in 0..n_turns.min(budget) {
        // Fresh user/tool span, unique to this (session, turn).
        let user_len = clamp_len(
            rng.lognormal(spec.user_span_median, 0.6),
            4,
            spec.max_input / 4,
        );
        prompt.extend(span(
            class,
            sid * 100_000 + turn as u64 * 2 + 1,
            user_len,
            spec.vocab,
        ));
        if prompt.len() > spec.max_input {
            prompt.truncate(spec.max_input);
        }
        let output_len =
            clamp_len(rng.lognormal(spec.output_median, spec.output_sigma), 1, 4096) as u32;

        let tokens: Arc<[u32]> = prompt.as_slice().into();
        let hashes = block_hashes(&tokens);
        // Deterministic assistant reply: the next turn's prompt (and
        // the completion-time cache chain) extend it.
        let assistant = span(
            class,
            sid * 100_000 + turn as u64 * 2 + 2,
            output_len as usize,
            spec.vocab,
        );
        prompt.extend(&assistant);
        let full_hashes = block_hashes(&prompt);

        let think_us = if turn == 0 {
            0
        } else {
            (rng.exp(spec.think_time_s) * 1e6) as u64
        };
        turns.push(SessionTurn {
            req: Request {
                id: 0, // dense ids assigned by the caller, in (session, turn) order
                arrival_us: if turn == 0 { start_us } else { 0 },
                class_id: class,
                session_id: sid,
                model_id: 0,
                tokens,
                output_len,
                block_hashes: hashes.into(),
            },
            full_hashes: full_hashes.into(),
            think_us,
        });
    }
    turns
}

/// Generate a closed-loop session trace. Deterministic in
/// `(spec.kind, spec.n_requests, spec.seed)`.
///
/// NOTE: the turn-chain construction mirrors [`super::generate`]'s
/// (that one open-loop, this one closed-loop); keep the span/truncate
/// arithmetic in sync with [`turn_growth`] and with synth's copy.
pub fn generate_sessions(spec: &SessionSpec) -> SessionTrace {
    let mut rng = Rng::new(spec.seed ^ ((spec.kind as u64) << 52) ^ 0x5e55_0000_0001);
    let zipf = Zipf::new(spec.n_classes, spec.class_skew);
    let mut sessions: Vec<Session> = Vec::new();
    let mut clock_s: f64 = 0.0;
    let mut total = 0usize;
    let mut sid: u64 = 0;

    while total < spec.n_requests {
        // Poisson session arrivals; the per-turn pacing inside a session
        // is reactive, so there is no burst modulation knob here — load
        // shape under pressure emerges from the closed loop itself.
        clock_s += rng.exp(1.0 / spec.session_rate);
        sid += 1;
        let class = zipf.sample(&mut rng) as u32;
        let start_us = (clock_s * 1e6) as u64;
        let budget = spec.n_requests - total;
        let turns = build_turn_chain(spec, &mut rng, class, sid, start_us, budget);
        total += turns.len();
        sessions.push(Session {
            sid,
            class_id: class,
            start_us,
            turns,
        });
    }

    // The arrival clock only moves forward, so sessions are already in
    // start order; the sort pins the invariant against future edits.
    sessions.sort_by_key(|s| (s.start_us, s.sid));
    let mut id = 0u64;
    for s in sessions.iter_mut() {
        for t in s.turns.iter_mut() {
            t.req.id = id;
            id += 1;
        }
    }
    SessionTrace {
        name: format!("sessions-{}", spec.kind.name()),
        sessions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::shared_blocks;

    #[test]
    fn deterministic_in_seed() {
        let a = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 300, 9));
        let b = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 300, 9));
        assert_eq!(a.n_turns(), b.n_turns());
        for (sa, sb) in a.sessions.iter().zip(&b.sessions) {
            assert_eq!(sa.start_us, sb.start_us);
            assert_eq!(sa.turns.len(), sb.turns.len());
            for (ta, tb) in sa.turns.iter().zip(&sb.turns) {
                assert_eq!(ta.req.tokens, tb.req.tokens);
                assert_eq!(ta.think_us, tb.think_us);
                assert_eq!(ta.full_hashes, tb.full_hashes);
            }
        }
        let c = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 300, 10));
        let differs = a
            .sessions
            .iter()
            .zip(&c.sessions)
            .any(|(sa, sc)| sa.start_us != sc.start_us || sa.turns.len() != sc.turns.len());
        assert!(differs, "different seeds must produce different schedules");
    }

    #[test]
    fn ids_dense_in_session_turn_order() {
        let t = generate_sessions(&SessionSpec::preset(SessionKind::ApiCall, 250, 3));
        assert_eq!(t.n_turns(), 250);
        let mut expect = 0u64;
        for s in &t.sessions {
            for turn in &s.turns {
                assert_eq!(turn.req.id, expect);
                assert_eq!(turn.req.session_id, s.sid);
                assert!(turn.req.session_id != 0, "0 is reserved for sessionless");
                expect += 1;
            }
        }
        for w in t.sessions.windows(2) {
            assert!(w[0].start_us <= w[1].start_us);
        }
        let idx = t.turn_index();
        assert_eq!(idx.len(), 250);
        assert_eq!(idx[&0], (0, 0));
    }

    #[test]
    fn turns_extend_previous_full_context() {
        let t = generate_sessions(&SessionSpec::preset(SessionKind::CodingAgent, 400, 5));
        let mut multi = 0;
        for s in &t.sessions {
            for w in s.turns.windows(2) {
                multi += 1;
                let prev_full = &w[0].full_hashes;
                let next = &w[1].req.block_hashes;
                // Next turn's prompt chain starts with the previous
                // turn's full chain (possibly truncated at max_input).
                let shared = shared_blocks(next, prev_full);
                assert_eq!(
                    shared,
                    prev_full.len().min(next.len()),
                    "turn must extend (a prefix of) the previous full chain"
                );
                assert!(w[1].think_us > 0, "reactive turns carry think time");
            }
        }
        assert!(multi > 50, "coding agents must be multi-turn");
    }

    #[test]
    fn generator_lengths_obey_turn_growth_recurrence() {
        let spec = SessionSpec::preset(SessionKind::Chat, 300, 21);
        let t = generate_sessions(&spec);
        for s in &t.sessions {
            if s.turns.is_empty() {
                continue;
            }
            // Anchor on the first prompt and walk the recurrence bound:
            // full_k >= prompt_k and prompt_{k+1} = min(full_k + user, max),
            // so prompt_{k+1} >= min(prompt_k, max) = prompt_k.
            let mut ctx = s.turns[0].req.tokens.len();
            for w in s.turns.windows(2) {
                let p_next = w[1].req.tokens.len();
                assert!(p_next <= spec.max_input);
                assert!(p_next >= ctx.min(spec.max_input), "prompts must grow");
                ctx = p_next;
            }
        }
        // And the closed form itself.
        let g = turn_growth(100, &[10, 20, 30], &[5, 5, 1000], 200);
        assert_eq!(g, vec![(110, 115), (135, 140), (170, 1170)]);
        let g2 = turn_growth(100, &[200, 10], &[50, 1], 250);
        assert_eq!(g2, vec![(250, 300), (250, 251)]); // truncation clamps
    }

    #[test]
    fn kind_shapes_differ() {
        let chat = generate_sessions(&SessionSpec::preset(SessionKind::Chat, 400, 1));
        let api = generate_sessions(&SessionSpec::preset(SessionKind::ApiCall, 400, 1));
        let coding = generate_sessions(&SessionSpec::preset(SessionKind::CodingAgent, 400, 1));
        let mean_turns = |t: &SessionTrace| t.n_turns() as f64 / t.sessions.len() as f64;
        assert!(mean_turns(&coding) > mean_turns(&api), "agents loop more");
        let mean_think = |t: &SessionTrace| {
            let (mut sum, mut n) = (0u64, 0u64);
            for s in &t.sessions {
                for turn in s.turns.iter().skip(1) {
                    sum += turn.think_us;
                    n += 1;
                }
            }
            sum as f64 / n.max(1) as f64
        };
        assert!(
            mean_think(&chat) > 4.0 * mean_think(&coding),
            "humans think slower than tools run"
        );
        let (chat_in, _) = chat.flatten().token_stats();
        let (api_in, _) = api.flatten().token_stats();
        assert!(chat_in > api_in, "api prompts shortest");
    }

    #[test]
    fn flatten_is_sorted_and_exact_for_single_turn() {
        let mut spec = SessionSpec::preset(SessionKind::Chat, 200, 4);
        spec.max_turns = 1;
        let st = generate_sessions(&spec);
        assert!(st.sessions.iter().all(|s| s.turns.len() == 1));
        let t = st.flatten();
        assert_eq!(t.requests.len(), 200);
        for w in t.requests.windows(2) {
            assert!(w[0].req.arrival_us <= w[1].req.arrival_us);
        }
        for (tr, s) in t.requests.iter().zip(&st.sessions) {
            assert_eq!(tr.req.arrival_us, s.start_us, "single turns keep start times");
        }
    }
}
