//! Workload traces: synthetic generators matching the paper's four trace
//! families (Fig 5 characteristics), a jsonl replayer format, the §4.1
//! rate-scaling methodology, the [`adversarial`] generators that
//! synthesize the failure-condition guard's misranking regimes on
//! demand (idle-fleet bursts, shared-prefix floods, spread-window
//! stress), and the closed-loop [`sessions`] engine (multi-turn
//! chat / API-call / coding-agent traces with reactive arrivals).

pub mod adversarial;
mod replay;
pub mod sessions;
mod synth;

pub use adversarial::{generate_adversarial, AdversarialScenario, AdversarialSpec};
pub use replay::{load_jsonl, save_jsonl};
pub use sessions::{
    generate_sessions, Session, SessionKind, SessionSpec, SessionTrace, SessionTurn,
};
pub use synth::{generate, Workload, WorkloadSpec};

use std::sync::Arc;

use crate::core::Request;

/// Clamp a sampled (lognormal) length into `[lo, hi]` — shared by the
/// synth and session generators.
pub(crate) fn clamp_len(x: f64, lo: usize, hi: usize) -> usize {
    (x as usize).clamp(lo, hi)
}

/// One trace entry: the request plus the block-hash chain of
/// prompt+output (what the instance caches at completion — the next
/// conversation turn's prompt extends it). `full_hashes` is `Arc`-shared
/// for the same reason as [`Request::tokens`]: the DES hands it to the
/// instance queue and to its completion bookkeeping map, and both hops
/// must be refcount bumps, not `Vec` copies.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub req: Request,
    pub full_hashes: Arc<[u64]>,
}

/// A replayable trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Mean request arrival rate over the trace span, requests/s.
    pub fn mean_rps(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        let span_us = self.requests.last().unwrap().req.arrival_us
            - self.requests.first().unwrap().req.arrival_us;
        if span_us == 0 {
            return f64::INFINITY;
        }
        self.requests.len() as f64 / (span_us as f64 / 1e6)
    }

    /// Steady-state request rate: the rate over the middle 50% of
    /// arrivals (by index), immune to the ramp-up head and the session
    /// tail that distort [`Trace::mean_rps`] on truncated traces.
    pub fn steady_rps(&self) -> f64 {
        let n = self.requests.len();
        if n < 8 {
            return self.mean_rps();
        }
        let lo = self.requests[n / 4].req.arrival_us;
        let hi = self.requests[3 * n / 4].req.arrival_us;
        if hi <= lo {
            return f64::INFINITY;
        }
        (n / 2) as f64 / ((hi - lo) as f64 / 1e6)
    }

    /// Rescale arrival times so the mean rate becomes `target_rps`
    /// (§4.1: traces are scaled to the testbed's capacity; burst
    /// structure is preserved because all gaps scale uniformly).
    pub fn scale_to_rps(&mut self, target_rps: f64) {
        let cur = self.mean_rps();
        if !cur.is_finite() || cur <= 0.0 || target_rps <= 0.0 {
            return;
        }
        let factor = cur / target_rps;
        let t0 = self.requests.first().map(|r| r.req.arrival_us).unwrap_or(0);
        for tr in self.requests.iter_mut() {
            let rel = tr.req.arrival_us - t0;
            tr.req.arrival_us = (rel as f64 * factor) as u64;
        }
    }

    /// Mean input/output token counts (Fig 5 style characterization).
    pub fn token_stats(&self) -> (f64, f64) {
        let n = self.requests.len().max(1) as f64;
        let inp: usize = self.requests.iter().map(|r| r.req.input_len()).sum();
        let out: u64 = self.requests.iter().map(|r| r.req.output_len as u64).sum();
        (inp as f64 / n, out as f64 / n)
    }

    /// Theoretical KV$ hit rate with an infinite, cluster-wide cache
    /// (Fig 5 bottom row): replay all prompts through one unbounded radix
    /// tree, counting hit blocks / looked-up blocks.
    pub fn infinite_cache_hit_rate(&self) -> f64 {
        let mut tree = crate::kvcache::RadixTree::new(0);
        let mut hit_tokens = 0usize;
        let mut total_tokens = 0usize;
        for tr in &self.requests {
            let hit =
                tree.match_prefix(&tr.req.block_hashes, tr.req.arrival_us, false);
            hit_tokens += (hit * crate::core::BLOCK_TOKENS).min(tr.req.input_len());
            total_tokens += tr.req.input_len();
            tree.insert(&tr.full_hashes, tr.req.arrival_us);
        }
        if total_tokens == 0 {
            0.0
        } else {
            hit_tokens as f64 / total_tokens as f64
        }
    }

    /// Truncate to the first `n` requests (quick-mode benches).
    pub fn truncate(&mut self, n: usize) {
        self.requests.truncate(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> Trace {
        generate(&WorkloadSpec::preset(Workload::ChatBot, 200, 1))
    }

    #[test]
    fn scaling_hits_target_rate() {
        let mut t = tiny_trace();
        t.scale_to_rps(25.0);
        assert!((t.mean_rps() - 25.0).abs() / 25.0 < 0.02, "rps={}", t.mean_rps());
    }

    #[test]
    fn scaling_preserves_order_and_ratios() {
        let mut t = tiny_trace();
        let gaps_before: Vec<f64> = t
            .requests
            .windows(2)
            .map(|w| (w[1].req.arrival_us - w[0].req.arrival_us) as f64)
            .collect();
        t.scale_to_rps(t.mean_rps() * 2.0);
        for w in t.requests.windows(2) {
            assert!(w[1].req.arrival_us >= w[0].req.arrival_us);
        }
        let gaps_after: Vec<f64> = t
            .requests
            .windows(2)
            .map(|w| (w[1].req.arrival_us - w[0].req.arrival_us) as f64)
            .collect();
        // Each gap roughly halves.
        for (b, a) in gaps_before.iter().zip(&gaps_after) {
            if *b > 1000.0 {
                assert!((a / b - 0.5).abs() < 0.01);
            }
        }
    }

    #[test]
    fn infinite_cache_hit_rate_positive_for_chatbot() {
        let t = tiny_trace();
        let rate = t.infinite_cache_hit_rate();
        // Multi-turn + shared system prompts => substantial reuse.
        assert!(rate > 0.2, "hit rate {rate}");
        assert!(rate < 0.98);
    }
}
