//! Workload traces: synthetic generators matching the paper's four trace
//! families (Fig 5 characteristics), a jsonl replayer format, the §4.1
//! rate-scaling methodology, the [`adversarial`] generators that
//! synthesize the failure-condition guard's misranking regimes on
//! demand (idle-fleet bursts, shared-prefix floods, spread-window
//! stress), the closed-loop [`sessions`] engine (multi-turn
//! chat / API-call / coding-agent traces with reactive arrivals), and
//! the [`open`] engine (open-system Poisson session arrivals under
//! time-varying rate programs, with heterogeneous archetype mixes).

pub mod adversarial;
pub mod open;
mod replay;
pub mod sessions;
mod synth;

pub use adversarial::{generate_adversarial, AdversarialScenario, AdversarialSpec};
pub use open::{generate_open, sample_arrivals, OpenSpec, RateProgram, RateSegment};
pub use replay::{load_jsonl, save_jsonl};
pub use sessions::{
    generate_sessions, Session, SessionKind, SessionSpec, SessionTrace, SessionTurn,
};
pub use synth::{generate, Workload, WorkloadSpec};

use std::sync::Arc;

use crate::core::Request;

/// Clamp a sampled (lognormal) length into `[lo, hi]` — shared by the
/// synth and session generators.
pub(crate) fn clamp_len(x: f64, lo: usize, hi: usize) -> usize {
    (x as usize).clamp(lo, hi)
}

/// One trace entry: the request plus the block-hash chain of
/// prompt+output (what the instance caches at completion — the next
/// conversation turn's prompt extends it). `full_hashes` is `Arc`-shared
/// for the same reason as [`Request::tokens`]: the DES hands it to the
/// instance queue and to its completion bookkeeping map, and both hops
/// must be refcount bumps, not `Vec` copies.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub req: Request,
    pub full_hashes: Arc<[u64]>,
}

/// A replayable trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Mean request arrival rate over the trace span, requests/s.
    pub fn mean_rps(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        let span_us = self.requests.last().unwrap().req.arrival_us
            - self.requests.first().unwrap().req.arrival_us;
        if span_us == 0 {
            return f64::INFINITY;
        }
        self.requests.len() as f64 / (span_us as f64 / 1e6)
    }

    /// Steady-state request rate: the rate over the middle 50% of
    /// arrivals (by index), immune to the ramp-up head and the session
    /// tail that distort [`Trace::mean_rps`] on truncated traces.
    pub fn steady_rps(&self) -> f64 {
        let n = self.requests.len();
        if n < 8 {
            return self.mean_rps();
        }
        let lo = self.requests[n / 4].req.arrival_us;
        let hi = self.requests[3 * n / 4].req.arrival_us;
        if hi <= lo {
            return f64::INFINITY;
        }
        (n / 2) as f64 / ((hi - lo) as f64 / 1e6)
    }

    /// A copy of this trace with arrival times rescaled so the mean rate
    /// becomes `target_rps` (§4.1: traces are scaled to the testbed's
    /// capacity; burst structure is preserved because all gaps scale
    /// uniformly). Builder-style: the receiver is untouched, so a trace
    /// whose `Arc`-shared token/hash chains are already handed out can
    /// be rescaled without mutating behind anyone's back.
    pub fn with_rps(&self, target_rps: f64) -> Trace {
        let mut out = self.clone();
        let cur = out.mean_rps();
        if !cur.is_finite() || cur <= 0.0 || target_rps <= 0.0 {
            return out;
        }
        let factor = cur / target_rps;
        let t0 = out.requests.first().map(|r| r.req.arrival_us).unwrap_or(0);
        for tr in out.requests.iter_mut() {
            let rel = tr.req.arrival_us - t0;
            tr.req.arrival_us = (rel as f64 * factor) as u64;
        }
        out
    }

    /// Rescale arrival times in place so the mean rate becomes
    /// `target_rps`. Deprecated in favour of the non-mutating
    /// [`Trace::with_rps`]; kept as a delegating shim for old callers.
    pub fn scale_to_rps(&mut self, target_rps: f64) {
        *self = self.with_rps(target_rps);
    }

    /// A copy holding only the first `n` requests (quick-mode benches).
    /// Builder-style counterpart of [`Trace::truncate`].
    pub fn take_n(&self, n: usize) -> Trace {
        let mut out = self.clone();
        out.requests.truncate(n);
        out
    }

    /// Mean input/output token counts (Fig 5 style characterization).
    pub fn token_stats(&self) -> (f64, f64) {
        let n = self.requests.len().max(1) as f64;
        let inp: usize = self.requests.iter().map(|r| r.req.input_len()).sum();
        let out: u64 = self.requests.iter().map(|r| r.req.output_len as u64).sum();
        (inp as f64 / n, out as f64 / n)
    }

    /// Theoretical KV$ hit rate with an infinite, cluster-wide cache
    /// (Fig 5 bottom row): replay all prompts through one unbounded radix
    /// tree, counting hit blocks / looked-up blocks.
    pub fn infinite_cache_hit_rate(&self) -> f64 {
        let mut tree = crate::kvcache::RadixTree::new(0);
        let mut hit_tokens = 0usize;
        let mut total_tokens = 0usize;
        for tr in &self.requests {
            let hit =
                tree.match_prefix(&tr.req.block_hashes, tr.req.arrival_us, false);
            hit_tokens += (hit * crate::core::BLOCK_TOKENS).min(tr.req.input_len());
            total_tokens += tr.req.input_len();
            tree.insert(&tr.full_hashes, tr.req.arrival_us);
        }
        if total_tokens == 0 {
            0.0
        } else {
            hit_tokens as f64 / total_tokens as f64
        }
    }

    /// Truncate in place to the first `n` requests. Deprecated in favour
    /// of the non-mutating [`Trace::take_n`]; kept as a delegating shim.
    pub fn truncate(&mut self, n: usize) {
        *self = self.take_n(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_trace() -> Trace {
        generate(&WorkloadSpec::preset(Workload::ChatBot, 200, 1))
    }

    #[test]
    fn scaling_hits_target_rate() {
        let mut t = tiny_trace();
        t.scale_to_rps(25.0);
        assert!((t.mean_rps() - 25.0).abs() / 25.0 < 0.02, "rps={}", t.mean_rps());
    }

    #[test]
    fn scaling_preserves_order_and_ratios() {
        let mut t = tiny_trace();
        let gaps_before: Vec<f64> = t
            .requests
            .windows(2)
            .map(|w| (w[1].req.arrival_us - w[0].req.arrival_us) as f64)
            .collect();
        t.scale_to_rps(t.mean_rps() * 2.0);
        for w in t.requests.windows(2) {
            assert!(w[1].req.arrival_us >= w[0].req.arrival_us);
        }
        let gaps_after: Vec<f64> = t
            .requests
            .windows(2)
            .map(|w| (w[1].req.arrival_us - w[0].req.arrival_us) as f64)
            .collect();
        // Each gap roughly halves.
        for (b, a) in gaps_before.iter().zip(&gaps_after) {
            if *b > 1000.0 {
                assert!((a / b - 0.5).abs() < 0.01);
            }
        }
    }

    #[test]
    fn builder_scaling_leaves_receiver_untouched_and_shims_delegate() {
        let t = tiny_trace();
        let before: Vec<u64> = t.requests.iter().map(|r| r.req.arrival_us).collect();
        let scaled = t.with_rps(30.0);
        assert!((scaled.mean_rps() - 30.0).abs() / 30.0 < 0.02);
        let after: Vec<u64> = t.requests.iter().map(|r| r.req.arrival_us).collect();
        assert_eq!(before, after, "with_rps must not mutate the receiver");
        // The in-place shims produce exactly the builder results.
        let mut shim = t.clone();
        shim.scale_to_rps(30.0);
        let shim_ts: Vec<u64> = shim.requests.iter().map(|r| r.req.arrival_us).collect();
        let built_ts: Vec<u64> = scaled.requests.iter().map(|r| r.req.arrival_us).collect();
        assert_eq!(shim_ts, built_ts);

        let taken = t.take_n(50);
        assert_eq!(taken.requests.len(), 50);
        assert_eq!(t.requests.len(), 200, "take_n must not mutate the receiver");
        let mut shim2 = t.clone();
        shim2.truncate(50);
        assert_eq!(shim2.requests.len(), 50);
        for (a, b) in taken.requests.iter().zip(&shim2.requests) {
            assert_eq!(a.req.id, b.req.id);
            assert_eq!(a.req.arrival_us, b.req.arrival_us);
        }
    }

    #[test]
    fn infinite_cache_hit_rate_positive_for_chatbot() {
        let t = tiny_trace();
        let rate = t.infinite_cache_hit_rate();
        // Multi-turn + shared system prompts => substantial reuse.
        assert!(rate > 0.2, "hit rate {rate}");
        assert!(rate < 0.98);
    }
}
