//! Trace persistence: one JSON object per line (jsonl), matching the
//! shape of the paper's open-sourced trace-replayer format — hashed
//! content is represented by the token ids themselves plus the output
//! span needed to reconstruct the full (prompt+output) cache chain.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::core::Request;
use crate::tokenizer::block_hashes;
use crate::util::json::Json;

use super::{Trace, TraceRequest};

/// Write a trace as jsonl.
pub fn save_jsonl(trace: &Trace, path: &Path) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for tr in &trace.requests {
        // Store the output span as the token suffix of the full chain.
        // We regenerate full_hashes at load; tokens are the ground truth.
        let obj = Json::obj(vec![
            ("id", Json::Num(tr.req.id as f64)),
            ("arrival_us", Json::Num(tr.req.arrival_us as f64)),
            ("class", Json::Num(tr.req.class_id as f64)),
            ("session", Json::Num(tr.req.session_id as f64)),
            ("output_len", Json::Num(tr.req.output_len as f64)),
            (
                "tokens",
                Json::Arr(tr.req.tokens.iter().map(|t| Json::Num(*t as f64)).collect()),
            ),
            (
                "full_hashes",
                Json::Arr(
                    tr.full_hashes
                        .iter()
                        .map(|h| Json::Str(format!("{h:016x}")))
                        .collect(),
                ),
            ),
        ]);
        writeln!(w, "{}", obj.to_string())?;
    }
    Ok(())
}

/// Load a jsonl trace.
pub fn load_jsonl(name: &str, path: &Path) -> Result<Trace, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let reader = std::io::BufReader::new(f);
    let mut requests = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.map_err(|e| format!("line {}: {e}", lineno + 1))?;
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(&line).map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let tokens: Vec<u32> = v
            .get("tokens")
            .and_then(|t| t.as_arr())
            .ok_or_else(|| format!("line {}: missing tokens", lineno + 1))?
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as u32)
            .collect();
        let full_hashes: Vec<u64> = v
            .get("full_hashes")
            .and_then(|t| t.as_arr())
            .map(|arr| {
                arr.iter()
                    .filter_map(|x| x.as_str())
                    .filter_map(|s| u64::from_str_radix(s, 16).ok())
                    .collect()
            })
            .unwrap_or_default();
        let hashes = block_hashes(&tokens);
        requests.push(TraceRequest {
            req: Request {
                id: v.get("id").and_then(|x| x.as_u64()).unwrap_or(lineno as u64),
                arrival_us: v.get("arrival_us").and_then(|x| x.as_u64()).unwrap_or(0),
                class_id: v.get("class").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
                // Absent in pre-session trace files: default sessionless.
                session_id: v.get("session").and_then(|x| x.as_u64()).unwrap_or(0),
                // Absent in pre-multiplexing trace files: default model.
                model_id: v.get("model").and_then(|x| x.as_u64()).unwrap_or(0) as u32,
                output_len: v.get("output_len").and_then(|x| x.as_u64()).unwrap_or(1) as u32,
                tokens: tokens.into(),
                block_hashes: hashes.into(),
            },
            full_hashes: full_hashes.into(),
        });
    }
    requests.sort_by_key(|r| r.req.arrival_us);
    Ok(Trace {
        name: name.to_string(),
        requests,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{generate, Workload, WorkloadSpec};

    #[test]
    fn jsonl_roundtrip() {
        let t = generate(&WorkloadSpec::preset(Workload::Agent, 50, 3));
        let dir = std::env::temp_dir().join("lmetric_test_traces");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.jsonl");
        save_jsonl(&t, &path).unwrap();
        let t2 = load_jsonl("agent", &path).unwrap();
        assert_eq!(t.requests.len(), t2.requests.len());
        for (a, b) in t.requests.iter().zip(&t2.requests) {
            assert_eq!(a.req.tokens, b.req.tokens);
            assert_eq!(a.req.arrival_us, b.req.arrival_us);
            assert_eq!(a.req.class_id, b.req.class_id);
            assert_eq!(a.req.session_id, b.req.session_id);
            assert_eq!(a.req.output_len, b.req.output_len);
            assert_eq!(a.req.block_hashes, b.req.block_hashes);
            assert_eq!(a.full_hashes, b.full_hashes);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_bad_json() {
        let dir = std::env::temp_dir().join("lmetric_test_traces");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "this is not json\n").unwrap();
        assert!(load_jsonl("x", &path).is_err());
        std::fs::remove_file(path).ok();
    }
}
