//! Open-system traffic: Poisson session arrivals under *time-varying
//! rate programs*, with heterogeneous archetype mixes in one trace.
//!
//! The closed-loop generator in [`super::sessions`] fixes the offered
//! load implicitly: each archetype's `session_rate` is constant and the
//! trace ends after `n_requests` turns. Production failure modes live in
//! the *open* regime instead — arrivals keep coming whether or not the
//! cluster keeps up, and the arrival rate itself moves (diurnal curves,
//! ramps, flash crowds). This module generates that regime:
//!
//! * a [`RateProgram`] is a composable piecewise sequence of
//!   [`RateSegment`]s (constant / ramp / diurnal / flash crowd), each
//!   with a closed-form rate integral so tests can compare realized
//!   arrival counts against `∫λ(t)dt` per segment;
//! * arrivals are sampled by **Poisson thinning**: a homogeneous
//!   process at the program's peak rate, keeping each candidate with
//!   probability `λ(t)/λ_peak` (mirrored and fuzzed out-of-band by
//!   `python/tests/test_rate_program.py`);
//! * each arrival starts a *session* of a Zipf-popular class, drawn from
//!   a weighted mix of the [`SessionKind`] archetypes, grown by the
//!   exact same turn-chain machinery as the closed-loop generator —
//!   later turns stay reactive (released at previous completion +
//!   think), only the session *starts* are open-loop.
//!
//! Class-id spaces are offset per archetype so e.g. chat class 3 and
//! API class 3 never alias to the same shared-prefix content.

use crate::util::rng::Zipf;
use crate::util::Rng;

use super::sessions::{build_turn_chain, Session, SessionKind, SessionSpec, SessionTrace};

/// One piece of a [`RateProgram`]: session-arrival rate λ(t) over a
/// local time span `[0, dur_s)`, with a closed-form integral.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateSegment {
    /// λ(t) = rps.
    Constant { rps: f64, dur_s: f64 },
    /// Linear ramp: λ(t) = from + (to − from)·t/dur.
    Ramp {
        from_rps: f64,
        to_rps: f64,
        dur_s: f64,
    },
    /// Diurnal curve: λ(t) = base·(1 + A·sin(2πt/P)), A ∈ [0, 1].
    Diurnal {
        base_rps: f64,
        amplitude: f64,
        period_s: f64,
        dur_s: f64,
    },
    /// Flash crowd: λ = base everywhere except ×`mult` on
    /// `[at_s, at_s + burst_s)`.
    Flash {
        base_rps: f64,
        mult: f64,
        at_s: f64,
        burst_s: f64,
        dur_s: f64,
    },
}

impl RateSegment {
    pub fn dur_s(&self) -> f64 {
        match *self {
            RateSegment::Constant { dur_s, .. }
            | RateSegment::Ramp { dur_s, .. }
            | RateSegment::Diurnal { dur_s, .. }
            | RateSegment::Flash { dur_s, .. } => dur_s,
        }
    }

    /// λ at local time `t` ∈ [0, dur).
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            RateSegment::Constant { rps, .. } => rps,
            RateSegment::Ramp { from_rps, to_rps, dur_s } => {
                from_rps + (to_rps - from_rps) * (t / dur_s)
            }
            RateSegment::Diurnal { base_rps, amplitude, period_s, .. } => {
                let w = 2.0 * std::f64::consts::PI / period_s;
                base_rps * (1.0 + amplitude * (w * t).sin())
            }
            RateSegment::Flash { base_rps, mult, at_s, burst_s, .. } => {
                if t >= at_s && t < at_s + burst_s {
                    base_rps * mult
                } else {
                    base_rps
                }
            }
        }
    }

    /// ∫₀ᵗ λ(u) du in closed form, local `t` ∈ [0, dur].
    pub fn integral_to(&self, t: f64) -> f64 {
        match *self {
            RateSegment::Constant { rps, .. } => rps * t,
            RateSegment::Ramp { from_rps, to_rps, dur_s } => {
                from_rps * t + (to_rps - from_rps) * t * t / (2.0 * dur_s)
            }
            RateSegment::Diurnal { base_rps, amplitude, period_s, .. } => {
                let w = 2.0 * std::f64::consts::PI / period_s;
                base_rps * (t + amplitude / w * (1.0 - (w * t).cos()))
            }
            RateSegment::Flash { base_rps, mult, at_s, burst_s, .. } => {
                let overlap = (t.min(at_s + burst_s) - at_s).max(0.0);
                base_rps * t + base_rps * (mult - 1.0) * overlap
            }
        }
    }

    /// An upper bound on λ over the segment (tight for all shapes).
    pub fn peak(&self) -> f64 {
        match *self {
            RateSegment::Constant { rps, .. } => rps,
            RateSegment::Ramp { from_rps, to_rps, .. } => from_rps.max(to_rps),
            RateSegment::Diurnal { base_rps, amplitude, .. } => base_rps * (1.0 + amplitude),
            RateSegment::Flash { base_rps, mult, .. } => base_rps * mult.max(1.0),
        }
    }

    /// The same shape with every rate field multiplied by `f` (the
    /// relative profile — ramp slope, diurnal amplitude ratio, flash
    /// multiplier — is preserved).
    pub fn scaled(&self, f: f64) -> RateSegment {
        match *self {
            RateSegment::Constant { rps, dur_s } => RateSegment::Constant {
                rps: rps * f,
                dur_s,
            },
            RateSegment::Ramp { from_rps, to_rps, dur_s } => RateSegment::Ramp {
                from_rps: from_rps * f,
                to_rps: to_rps * f,
                dur_s,
            },
            RateSegment::Diurnal { base_rps, amplitude, period_s, dur_s } => RateSegment::Diurnal {
                base_rps: base_rps * f,
                amplitude,
                period_s,
                dur_s,
            },
            RateSegment::Flash { base_rps, mult, at_s, burst_s, dur_s } => RateSegment::Flash {
                base_rps: base_rps * f,
                mult,
                at_s,
                burst_s,
                dur_s,
            },
        }
    }

    fn shape_name(&self) -> &'static str {
        match self {
            RateSegment::Constant { .. } => "constant",
            RateSegment::Ramp { .. } => "ramp",
            RateSegment::Diurnal { .. } => "diurnal",
            RateSegment::Flash { .. } => "flash",
        }
    }
}

/// A piecewise rate program: segments played back to back. Time past the
/// last segment has rate 0 (the trace simply ends).
#[derive(Debug, Clone, PartialEq)]
pub struct RateProgram {
    pub segments: Vec<RateSegment>,
}

impl RateProgram {
    pub fn new(segments: Vec<RateSegment>) -> RateProgram {
        RateProgram { segments }
    }

    pub fn constant(rps: f64, dur_s: f64) -> RateProgram {
        RateProgram::new(vec![RateSegment::Constant { rps, dur_s }])
    }

    pub fn ramp(from_rps: f64, to_rps: f64, dur_s: f64) -> RateProgram {
        RateProgram::new(vec![RateSegment::Ramp {
            from_rps,
            to_rps,
            dur_s,
        }])
    }

    pub fn diurnal(base_rps: f64, amplitude: f64, period_s: f64, dur_s: f64) -> RateProgram {
        debug_assert!((0.0..=1.0).contains(&amplitude), "amplitude in [0,1]");
        RateProgram::new(vec![RateSegment::Diurnal {
            base_rps,
            amplitude,
            period_s,
            dur_s,
        }])
    }

    pub fn flash_crowd(
        base_rps: f64,
        mult: f64,
        at_s: f64,
        burst_s: f64,
        dur_s: f64,
    ) -> RateProgram {
        RateProgram::new(vec![RateSegment::Flash {
            base_rps,
            mult,
            at_s,
            burst_s,
            dur_s,
        }])
    }

    /// Append another segment (builder-style composition).
    pub fn then(mut self, seg: RateSegment) -> RateProgram {
        self.segments.push(seg);
        self
    }

    pub fn duration_s(&self) -> f64 {
        self.segments.iter().map(|s| s.dur_s()).sum()
    }

    /// λ at global time `t` (0 outside the program).
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut start = 0.0;
        for seg in &self.segments {
            let end = start + seg.dur_s();
            if t >= start && t < end {
                return seg.rate_at(t - start);
            }
            start = end;
        }
        0.0
    }

    /// ∫ λ(t) dt over `[t0, t1]`, in closed form per segment.
    pub fn integral(&self, t0: f64, t1: f64) -> f64 {
        let mut total = 0.0;
        let mut start = 0.0;
        for seg in &self.segments {
            let end = start + seg.dur_s();
            let lo = (t0.max(start) - start).clamp(0.0, seg.dur_s());
            let hi = (t1.min(end) - start).clamp(0.0, seg.dur_s());
            if hi > lo {
                total += seg.integral_to(hi) - seg.integral_to(lo);
            }
            start = end;
        }
        total
    }

    /// Peak rate across all segments (the thinning envelope).
    pub fn peak_rate(&self) -> f64 {
        self.segments.iter().map(|s| s.peak()).fold(0.0, f64::max)
    }

    /// Mean rate over the whole program.
    pub fn mean_rate(&self) -> f64 {
        let d = self.duration_s();
        if d > 0.0 {
            self.integral(0.0, d) / d
        } else {
            0.0
        }
    }

    /// The program with every segment's rates multiplied by `f`.
    pub fn scaled(&self, f: f64) -> RateProgram {
        RateProgram::new(self.segments.iter().map(|s| s.scaled(f)).collect())
    }

    /// A short shape label ("constant", "ramp+flash", ...) for trace names.
    pub fn label(&self) -> String {
        let names: Vec<&str> = self.segments.iter().map(|s| s.shape_name()).collect();
        names.join("+")
    }
}

/// Sample arrival times (seconds) of a non-homogeneous Poisson process
/// following `program`, by thinning a homogeneous process at the peak
/// rate. The draw order — one `exp` gap, then one `gen_bool` accept per
/// candidate — is a compatibility contract with the Python mirror suite
/// (`python/tests/test_rate_program.py`).
pub fn sample_arrivals(program: &RateProgram, rng: &mut Rng) -> Vec<f64> {
    let peak = program.peak_rate();
    let end = program.duration_s();
    let mut out = Vec::new();
    if peak <= 0.0 || end <= 0.0 {
        return out;
    }
    let mut t = 0.0;
    loop {
        t += rng.exp(1.0 / peak);
        if t >= end {
            break;
        }
        if rng.gen_bool(program.rate_at(t) / peak) {
            out.push(t);
        }
    }
    out
}

/// Spec for one open-arrival trace: a rate program driving session
/// starts, a weighted archetype mix, and an optional global turn cap.
#[derive(Debug, Clone)]
pub struct OpenSpec {
    /// Session-start arrival rate over time (sessions/s).
    pub program: RateProgram,
    /// Archetype mix: `(kind, weight)` pairs; weights need not sum to 1.
    pub mix: Vec<(SessionKind, f64)>,
    pub seed: u64,
    /// Cap on total turns across the trace (0 = uncapped: the program's
    /// duration alone bounds the trace).
    pub max_requests: usize,
}

impl OpenSpec {
    /// Default production-flavoured mix: half chat, a third API chains,
    /// the rest coding agents.
    pub fn new(program: RateProgram, seed: u64) -> OpenSpec {
        OpenSpec {
            program,
            mix: vec![
                (SessionKind::Chat, 0.5),
                (SessionKind::ApiCall, 0.3),
                (SessionKind::CodingAgent, 0.2),
            ],
            seed,
            max_requests: 0,
        }
    }

    pub fn with_mix(mut self, mix: Vec<(SessionKind, f64)>) -> OpenSpec {
        self.mix = mix;
        self
    }

    pub fn with_cap(mut self, max_requests: usize) -> OpenSpec {
        self.max_requests = max_requests;
        self
    }

    /// The disjoint class-id range each archetype's sessions draw from
    /// (ranges are offset so archetypes never alias shared prefixes).
    /// Matches [`generate_open`]'s assignment exactly.
    pub fn class_ranges(&self) -> Vec<(SessionKind, std::ops::Range<u32>)> {
        let mut out = Vec::with_capacity(self.mix.len());
        let mut offset = 0u32;
        for &(kind, _) in &self.mix {
            let n = SessionSpec::preset(kind, 0, self.seed).n_classes as u32;
            out.push((kind, offset..offset + n));
            offset += n;
        }
        out
    }
}

/// Generate an open-arrival session trace: session starts follow the
/// rate program; each session's archetype is drawn from the mix and its
/// turn chain grows through the same machinery (and with the same
/// statistics) as [`super::generate_sessions`]. Later turns of a session
/// stay reactive — only the *starts* are open-loop. Deterministic in
/// `spec` (seed, program, mix, cap).
pub fn generate_open(spec: &OpenSpec) -> SessionTrace {
    assert!(!spec.mix.is_empty(), "open trace needs at least one archetype");
    let mut root = Rng::new(spec.seed ^ 0x09e4_0000_0007);
    let mut arrival_rng = root.fork(1);
    let mut session_rng = root.fork(2);

    // Per-kind presets, Zipf samplers, and disjoint class-id offsets.
    let weights: Vec<f64> = spec.mix.iter().map(|&(_, w)| w).collect();
    let mut kinds: Vec<(SessionSpec, Zipf, u32)> = Vec::with_capacity(spec.mix.len());
    let mut offset = 0u32;
    for &(kind, _) in &spec.mix {
        let kspec = SessionSpec::preset(kind, 0, spec.seed);
        let zipf = Zipf::new(kspec.n_classes, kspec.class_skew);
        let n = kspec.n_classes as u32;
        kinds.push((kspec, zipf, offset));
        offset += n;
    }

    let starts = sample_arrivals(&spec.program, &mut arrival_rng);
    let budget_total = if spec.max_requests == 0 {
        usize::MAX
    } else {
        spec.max_requests
    };
    let mut total = 0usize;
    let mut sessions: Vec<Session> = Vec::with_capacity(starts.len());
    let mut sid: u64 = 0;
    for t_s in starts {
        if total >= budget_total {
            break;
        }
        sid += 1;
        let ki = session_rng.categorical(&weights);
        let (kspec, zipf, class_offset) = &kinds[ki];
        let class = zipf.sample(&mut session_rng) as u32 + class_offset;
        let start_us = (t_s * 1e6) as u64;
        let budget = budget_total - total;
        let turns = build_turn_chain(kspec, &mut session_rng, class, sid, start_us, budget);
        total += turns.len();
        sessions.push(Session {
            sid,
            class_id: class,
            start_us,
            turns,
        });
    }

    sessions.sort_by_key(|s| (s.start_us, s.sid));
    let mut id = 0u64;
    for s in sessions.iter_mut() {
        for t in s.turns.iter_mut() {
            t.req.id = id;
            id += 1;
        }
    }
    SessionTrace {
        name: format!("open-{}", spec.program.label()),
        sessions,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numeric_integral(p: &RateProgram, t0: f64, t1: f64) -> f64 {
        let n = 20_000;
        let dt = (t1 - t0) / n as f64;
        (0..n).map(|i| p.rate_at(t0 + (i as f64 + 0.5) * dt) * dt).sum()
    }

    #[test]
    fn closed_form_integrals_match_quadrature() {
        let programs = [
            RateProgram::constant(4.0, 60.0),
            RateProgram::ramp(1.0, 9.0, 120.0),
            RateProgram::diurnal(5.0, 0.6, 40.0, 100.0),
            RateProgram::flash_crowd(3.0, 6.0, 20.0, 10.0, 80.0),
            RateProgram::constant(2.0, 30.0)
                .then(RateSegment::Ramp {
                    from_rps: 2.0,
                    to_rps: 8.0,
                    dur_s: 40.0,
                })
                .then(RateSegment::Flash {
                    base_rps: 8.0,
                    mult: 3.0,
                    at_s: 5.0,
                    burst_s: 10.0,
                    dur_s: 30.0,
                }),
        ];
        for p in &programs {
            let d = p.duration_s();
            for (t0, t1) in [(0.0, d), (0.1 * d, 0.7 * d), (0.5 * d, 0.9 * d)] {
                let exact = p.integral(t0, t1);
                let approx = numeric_integral(p, t0, t1);
                assert!(
                    (exact - approx).abs() < 1e-2 * approx.max(1.0),
                    "{}: integral({t0},{t1}) exact {exact} vs quad {approx}",
                    p.label()
                );
            }
        }
    }

    #[test]
    fn realized_counts_match_integral_per_segment() {
        // ±(5σ + 5) with σ = √Λ keeps this seed-stable while still
        // catching systematic thinning errors.
        let p = RateProgram::constant(6.0, 200.0)
            .then(RateSegment::Ramp {
                from_rps: 6.0,
                to_rps: 18.0,
                dur_s: 200.0,
            })
            .then(RateSegment::Diurnal {
                base_rps: 12.0,
                amplitude: 0.5,
                period_s: 60.0,
                dur_s: 200.0,
            });
        let mut rng = Rng::new(77);
        let arrivals = sample_arrivals(&p, &mut rng);
        let mut start = 0.0;
        for seg in &p.segments {
            let end = start + seg.dur_s();
            let expected = p.integral(start, end);
            let got = arrivals.iter().filter(|&&t| t >= start && t < end).count() as f64;
            let tol = 5.0 * expected.sqrt() + 5.0;
            assert!(
                (got - expected).abs() < tol,
                "segment [{start},{end}): got {got}, expected {expected} ± {tol}"
            );
            start = end;
        }
        let total_expected = p.integral(0.0, p.duration_s());
        let tol = 5.0 * total_expected.sqrt() + 5.0;
        assert!((arrivals.len() as f64 - total_expected).abs() < tol);
    }

    #[test]
    fn flash_crowd_burst_is_aligned_and_dense() {
        let p = RateProgram::flash_crowd(2.0, 10.0, 100.0, 20.0, 300.0);
        let mut rng = Rng::new(5);
        let arrivals = sample_arrivals(&p, &mut rng);
        let in_burst = arrivals.iter().filter(|&&t| (100.0..120.0).contains(&t)).count();
        let before = arrivals.iter().filter(|&&t| (60.0..100.0).contains(&t)).count();
        // Burst window: λ = 20 over 20 s (Λ = 400); the 40 s right before
        // it: λ = 2 (Λ = 80). Densities must separate decisively.
        let burst_density = in_burst as f64 / 20.0;
        let base_density = before as f64 / 40.0;
        assert!(
            burst_density > 4.0 * base_density,
            "burst {burst_density}/s vs base {base_density}/s"
        );
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
    }

    #[test]
    fn generate_open_is_deterministic_and_mixed() {
        let spec = OpenSpec::new(RateProgram::constant(8.0, 120.0), 42);
        let a = generate_open(&spec);
        let b = generate_open(&spec);
        assert_eq!(a.n_turns(), b.n_turns());
        for (sa, sb) in a.sessions.iter().zip(&b.sessions) {
            assert_eq!(sa.start_us, sb.start_us);
            assert_eq!(sa.class_id, sb.class_id);
            assert_eq!(sa.turns.len(), sb.turns.len());
            for (ta, tb) in sa.turns.iter().zip(&sb.turns) {
                assert_eq!(ta.req.tokens, tb.req.tokens);
                assert_eq!(ta.think_us, tb.think_us);
            }
        }
        // Every archetype of the default mix shows up, identified by its
        // disjoint class range.
        let ranges = spec.class_ranges();
        assert_eq!(ranges.len(), 3);
        for (kind, range) in &ranges {
            let n = a.sessions.iter().filter(|s| range.contains(&s.class_id)).count();
            assert!(n > 0, "archetype {} missing from the mix", kind.name());
        }
        // Ranges tile the class-id space with no overlap.
        for w in ranges.windows(2) {
            assert_eq!(w[0].1.end, w[1].1.start);
        }
        // Dense ids in (session, turn) order, session ids nonzero.
        let mut expect = 0u64;
        for s in &a.sessions {
            assert!(s.sid != 0);
            for t in &s.turns {
                assert_eq!(t.req.id, expect);
                expect += 1;
            }
        }
    }

    #[test]
    fn open_cap_bounds_turns() {
        let spec = OpenSpec::new(RateProgram::constant(8.0, 600.0), 7).with_cap(250);
        let t = generate_open(&spec);
        assert_eq!(t.n_turns(), 250, "cap must bind on a long program");
        let uncapped = generate_open(&OpenSpec::new(RateProgram::constant(8.0, 600.0), 7));
        assert!(uncapped.n_turns() > 250);
        // The capped trace is a prefix of the uncapped one (same seed →
        // same draws until the cap bites).
        for (sa, sb) in t.sessions.iter().zip(&uncapped.sessions) {
            assert_eq!(sa.start_us, sb.start_us);
            assert_eq!(sa.class_id, sb.class_id);
        }
    }

    #[test]
    fn scaled_program_scales_mean_rate_and_load() {
        let p = RateProgram::ramp(2.0, 6.0, 100.0);
        let p2 = p.scaled(2.0);
        assert!((p2.mean_rate() - 2.0 * p.mean_rate()).abs() < 1e-9);
        assert!((p2.peak_rate() - 12.0).abs() < 1e-9);
        assert!((p2.duration_s() - p.duration_s()).abs() < 1e-12);
        // More sessions arrive under the scaled program.
        let lo = generate_open(&OpenSpec::new(p, 3));
        let hi = generate_open(&OpenSpec::new(p2, 3));
        assert!(hi.sessions.len() > lo.sessions.len());
    }

    #[test]
    fn reactive_turns_carry_think_time() {
        let spec = OpenSpec::new(RateProgram::constant(6.0, 120.0), 11);
        let t = generate_open(&spec);
        let mut multi = 0usize;
        for s in &t.sessions {
            for (ti, turn) in s.turns.iter().enumerate() {
                if ti == 0 {
                    assert_eq!(turn.req.arrival_us, s.start_us);
                    assert_eq!(turn.think_us, 0);
                } else {
                    assert!(turn.think_us > 0);
                    multi += 1;
                }
            }
        }
        assert!(multi > 20, "mix must contain multi-turn sessions");
    }
}
