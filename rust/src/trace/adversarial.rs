//! Adversarial workload generators for the failure-condition guard:
//! traces (and raw router snapshots) that synthesize, on demand, the
//! regimes where the multiplicative score provably degrades — so the
//! detector's true/false-positive behaviour is *measurable* instead of
//! asserted. Three scenario families:
//!
//! * **IdleFleetBurst** — simultaneous same-length bursts into a fully
//!   drained fleet. Every wave leader sees `BS == 0` everywhere and an
//!   identical P-token on every instance: the all-idle degenerate tie.
//! * **SharedPrefixFlood** — waves of byte-identical prompts separated
//!   by drain gaps. After the first wave several instances hold the
//!   full prompt, so wave leaders see `P-token == 0` on ≥ 2 instances:
//!   the zero-annihilation degeneracy.
//! * **SpreadStress** — a sticky-decode hot class (long shared prefix,
//!   long outputs) over background singletons: KV-axis and load-axis
//!   spreads open up simultaneously, the cross-spread inversion
//!   precondition.
//!
//! Plus two snapshot-level generators ([`spread_route_ctx`],
//! [`degenerate_tie_ctx`]) that craft `RouteCtx` states at *chosen*
//! spread ratios directly — the spread-window sweep of
//! `fig33_guard_sweep` and the property suite drive the analyzer
//! through its whole detection window with them.

use crate::core::{Request, BLOCK_TOKENS};
use crate::router::{Indicators, RouteCtx};
use crate::tokenizer::{block_hashes, span};
use crate::util::Rng;

use super::{Trace, TraceRequest};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdversarialScenario {
    IdleFleetBurst,
    SharedPrefixFlood,
    SpreadStress,
}

impl AdversarialScenario {
    pub fn name(&self) -> &'static str {
        match self {
            AdversarialScenario::IdleFleetBurst => "idle_fleet_burst",
            AdversarialScenario::SharedPrefixFlood => "shared_prefix_flood",
            AdversarialScenario::SpreadStress => "spread_stress",
        }
    }
}

/// Parameters of one adversarial trace.
#[derive(Debug, Clone)]
pub struct AdversarialSpec {
    pub scenario: AdversarialScenario,
    pub n_requests: usize,
    pub seed: u64,
    pub vocab: u32,
    /// Requests per wave (burst scenarios); hot-class share driver for
    /// `SpreadStress` is fixed at 1/2.
    pub burst_size: usize,
    /// Idle gap between waves in seconds (long enough for the fleet to
    /// drain), or the mean inter-arrival time for `SpreadStress`.
    pub gap_s: f64,
    /// Prompt length in tokens. Block-multiple, so a fully cached
    /// prompt collapses P-token to exactly 0.
    pub prompt_len: usize,
    /// Output tokens per request (`SpreadStress` hot class overrides
    /// with sticky long decodes).
    pub output_len: u32,
    /// Background classes (`SpreadStress`).
    pub n_classes: usize,
}

impl AdversarialSpec {
    pub fn preset(scenario: AdversarialScenario, n_requests: usize, seed: u64) -> AdversarialSpec {
        let base = AdversarialSpec {
            scenario,
            n_requests,
            seed,
            vocab: 50_000,
            burst_size: 8,
            gap_s: 240.0,
            prompt_len: 512,
            output_len: 8,
            n_classes: 6,
        };
        match scenario {
            AdversarialScenario::IdleFleetBurst => base,
            AdversarialScenario::SharedPrefixFlood => AdversarialSpec {
                burst_size: 16,
                gap_s: 180.0,
                prompt_len: 4096,
                output_len: 16,
                ..base
            },
            AdversarialScenario::SpreadStress => AdversarialSpec {
                gap_s: 0.04,
                prompt_len: 4096,
                output_len: 64,
                ..base
            },
        }
    }
}

/// Generate an adversarial trace. Deterministic in
/// `(spec.scenario, spec.n_requests, spec.seed)`.
pub fn generate_adversarial(spec: &AdversarialSpec) -> Trace {
    let mut rng = Rng::new(spec.seed ^ ((spec.scenario as u64) << 40) ^ 0xadf0_0d01);
    let mut requests: Vec<TraceRequest> = Vec::with_capacity(spec.n_requests);
    let salt_base = spec.seed.wrapping_mul(1_000_003);
    match spec.scenario {
        AdversarialScenario::IdleFleetBurst => {
            let mut t_us: u64 = 0;
            let mut wave: u64 = 0;
            while requests.len() < spec.n_requests {
                for slot in 0..spec.burst_size {
                    if requests.len() >= spec.n_requests {
                        break;
                    }
                    // Unique content per (seed, wave, slot): no request
                    // ever hits another's prefix — pure idle ties.
                    let salt = salt_base + wave * 10_000 + slot as u64;
                    push_request(
                        &mut requests,
                        slot as u32,
                        t_us,
                        span(slot as u32, salt, spec.prompt_len, spec.vocab),
                        spec.output_len,
                        salt,
                        spec.vocab,
                    );
                }
                t_us += (spec.gap_s * 1e6) as u64;
                wave += 1;
            }
        }
        AdversarialScenario::SharedPrefixFlood => {
            // ONE prompt for the whole flood (per seed): after the first
            // wave is served and cached, wave leaders see P-token = 0 on
            // every instance that ever served it.
            let prompt = span(7, salt_base, spec.prompt_len, spec.vocab);
            let mut t_us: u64 = 0;
            let mut k: u64 = 0;
            while requests.len() < spec.n_requests {
                for _ in 0..spec.burst_size {
                    if requests.len() >= spec.n_requests {
                        break;
                    }
                    k += 1;
                    push_request(
                        &mut requests,
                        7,
                        t_us,
                        prompt.clone(),
                        spec.output_len,
                        salt_base + k,
                        spec.vocab,
                    );
                }
                t_us += (spec.gap_s * 1e6) as u64;
            }
        }
        AdversarialScenario::SpreadStress => {
            let hot_class = spec.n_classes as u32;
            let hot_prefix = span(hot_class, salt_base, spec.prompt_len, spec.vocab);
            let mut t_s: f64 = 0.0;
            let mut k: u64 = 0;
            while requests.len() < spec.n_requests {
                t_s += rng.exp(spec.gap_s);
                k += 1;
                let t_us = (t_s * 1e6) as u64;
                if rng.gen_bool(0.5) {
                    // Hot: share a variable-depth slice of the prefix
                    // (partial hits -> mid-range KV values) and decode
                    // long (sticky batches -> load spread).
                    let depth_blocks = [
                        spec.prompt_len / BLOCK_TOKENS / 2,
                        spec.prompt_len / BLOCK_TOKENS * 3 / 4,
                        spec.prompt_len / BLOCK_TOKENS,
                    ][rng.gen_range(0, 3) as usize];
                    let mut prompt = hot_prefix[..depth_blocks * BLOCK_TOKENS].to_vec();
                    prompt.extend(span(
                        hot_class,
                        salt_base + k,
                        rng.gen_range(1, 12) as usize * BLOCK_TOKENS,
                        spec.vocab,
                    ));
                    push_request(
                        &mut requests,
                        hot_class,
                        t_us,
                        prompt,
                        16 * spec.output_len,
                        salt_base + k,
                        spec.vocab,
                    );
                } else {
                    let class = rng.gen_range(0, spec.n_classes as u64) as u32;
                    let mut prompt = span(class, 0, 256, spec.vocab);
                    prompt.extend(span(class, salt_base + k, 768, spec.vocab));
                    push_request(
                        &mut requests,
                        class,
                        t_us,
                        prompt,
                        spec.output_len,
                        salt_base + k,
                        spec.vocab,
                    );
                }
            }
        }
    }
    requests.sort_by_key(|r| r.req.arrival_us);
    for (i, r) in requests.iter_mut().enumerate() {
        r.req.id = i as u64;
    }
    Trace {
        name: format!("adversarial_{}", spec.scenario.name()),
        requests,
    }
}

fn push_request(
    requests: &mut Vec<TraceRequest>,
    class: u32,
    arrival_us: u64,
    prompt: Vec<u32>,
    output_len: u32,
    salt: u64,
    vocab: u32,
) {
    let hashes = block_hashes(&prompt);
    let mut full = prompt.clone();
    full.extend(span(class, salt ^ 0x0a57, output_len as usize, vocab));
    let full_hashes = block_hashes(&full);
    requests.push(TraceRequest {
        req: Request {
            id: 0, // re-assigned in arrival order by the caller
            arrival_us,
            class_id: class,
            session_id: 0,
            model_id: 0,
            tokens: prompt.into(),
            output_len,
            block_hashes: hashes.into(),
        },
        full_hashes: full_hashes.into(),
    });
}

/// Craft a router snapshot whose two indicator axes sit at the chosen
/// cross-instance spread ratios (`kv_spread`, `load_spread` = max/min),
/// anti-correlated (small KV ↔ large load — the cross-spread regime).
/// Values are realized through DES-plausible fields: block-aligned
/// prefix hits, queued prefill carried by a queued batch member. The
/// spread-window sweep walks the analyzer's whole detection window with
/// these.
pub fn spread_route_ctx(
    rng: &mut Rng,
    n: usize,
    input_len: usize,
    kv_spread: f64,
    load_spread: f64,
) -> RouteCtx {
    assert!(n >= 2);
    let mut hit_tokens = vec![0usize; n];
    let mut inds = vec![Indicators::default(); n];
    let k_base = (input_len as f64 / kv_spread.max(1.0)).max(1.0);
    for i in 0..n {
        let frac = i as f64 / (n - 1) as f64;
        // KV ladder ascends, load ladder descends: anti-correlated.
        let k_target = k_base * kv_spread.max(1.0).powf(frac) * rng.gen_f64(0.95, 1.05);
        let l_target = (2.0 * load_spread.max(1.0).powf(1.0 - frac)).round().max(2.0);
        let k = k_target.round().max(0.0) as usize;
        let (hit, queued) = if k <= input_len {
            // hit must be block-aligned and >= input - k: round UP.
            let hit = ((input_len - k).div_ceil(BLOCK_TOKENS) * BLOCK_TOKENS).min(input_len);
            (hit, k - (input_len - hit))
        } else {
            (0, k - input_len)
        };
        let bs = l_target as usize - 1;
        let q_bs = if queued > 0 { 1 } else { 0 };
        hit_tokens[i] = hit;
        inds[i] = Indicators {
            r_bs: bs.saturating_sub(q_bs),
            q_bs,
            queued_prefill_tokens: queued,
            ..Default::default()
        };
    }
    RouteCtx::new(rng.next_u64() % 1_000_000_000, rng.next_u64(), 0, input_len, hit_tokens, inds)
}

/// Craft an all-idle degenerate tie: every instance at `BS == 0`, all
/// products exactly equal, but *different* prefix hits (queued prefill
/// compensates). Bare `select_min` resolves this 0-spread tie by lowest
/// index; the guard's secondary key must pick the max-hit instance.
/// (Deliberately outside the DES-reachable state space — queued tokens
/// without queued batch members — which is exactly why natural traffic
/// never trips the mitigation.)
pub fn degenerate_tie_ctx(rng: &mut Rng, n: usize, input_len: usize) -> RouteCtx {
    assert!(n >= 2);
    let blocks = input_len / BLOCK_TOKENS;
    let mut hit_tokens = vec![0usize; n];
    let mut inds = vec![Indicators::default(); n];
    for i in 0..n {
        let hit = rng.gen_range(0, blocks as u64 + 1) as usize * BLOCK_TOKENS;
        // p_token = queued + (input - hit) == input for every instance.
        hit_tokens[i] = hit.min(input_len);
        inds[i].queued_prefill_tokens = hit_tokens[i];
    }
    RouteCtx::new(rng.next_u64() % 1_000_000_000, rng.next_u64(), 0, input_len, hit_tokens, inds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::shared_blocks;

    #[test]
    fn deterministic_per_seed() {
        for scenario in [
            AdversarialScenario::IdleFleetBurst,
            AdversarialScenario::SharedPrefixFlood,
            AdversarialScenario::SpreadStress,
        ] {
            let a = generate_adversarial(&AdversarialSpec::preset(scenario, 300, 9));
            let b = generate_adversarial(&AdversarialSpec::preset(scenario, 300, 9));
            assert_eq!(a.requests.len(), b.requests.len());
            for (x, y) in a.requests.iter().zip(&b.requests) {
                assert_eq!(x.req.tokens, y.req.tokens, "{}", scenario.name());
                assert_eq!(x.req.arrival_us, y.req.arrival_us);
                assert_eq!(x.full_hashes, y.full_hashes);
            }
            let c = generate_adversarial(&AdversarialSpec::preset(scenario, 300, 10));
            assert!(
                a.requests.iter().zip(&c.requests).any(|(x, y)| x.req.tokens != y.req.tokens),
                "{}: different seed must change content",
                scenario.name()
            );
        }
    }

    #[test]
    fn idle_bursts_arrive_simultaneously_with_drain_gaps() {
        let spec = AdversarialSpec::preset(AdversarialScenario::IdleFleetBurst, 64, 3);
        let t = generate_adversarial(&spec);
        assert_eq!(t.requests.len(), 64);
        let gap_us = (spec.gap_s * 1e6) as u64;
        for (i, tr) in t.requests.iter().enumerate() {
            let wave = i / spec.burst_size;
            assert_eq!(tr.req.arrival_us, wave as u64 * gap_us, "request {i}");
            assert_eq!(tr.req.input_len(), spec.prompt_len, "equal-length ties");
        }
        // No cross-request prefix sharing: every tie is a pure idle tie.
        let a = &t.requests[0];
        let b = &t.requests[1];
        assert_eq!(shared_blocks(&a.req.block_hashes, &b.req.block_hashes), 0);
    }

    #[test]
    fn flood_prompts_are_identical_and_block_aligned() {
        let spec = AdversarialSpec::preset(AdversarialScenario::SharedPrefixFlood, 80, 5);
        let t = generate_adversarial(&spec);
        assert_eq!(spec.prompt_len % BLOCK_TOKENS, 0, "exact P-token collapse");
        let first = &t.requests[0];
        for tr in &t.requests {
            assert_eq!(tr.req.tokens, first.req.tokens, "one prompt floods the fleet");
            assert_eq!(tr.req.class_id, 7);
        }
        // Waves are separated by drain gaps.
        let w0_end = t.requests[spec.burst_size - 1].req.arrival_us;
        let w1_start = t.requests[spec.burst_size].req.arrival_us;
        assert!(w1_start >= w0_end + (spec.gap_s * 0.9 * 1e6) as u64);
    }

    #[test]
    fn stress_mixes_sticky_hot_class_with_background() {
        let spec = AdversarialSpec::preset(AdversarialScenario::SpreadStress, 600, 11);
        let t = generate_adversarial(&spec);
        let hot: Vec<_> = t
            .requests
            .iter()
            .filter(|r| r.req.class_id == spec.n_classes as u32)
            .collect();
        let share = hot.len() as f64 / t.requests.len() as f64;
        assert!((0.35..0.65).contains(&share), "hot share {share}");
        // Hot requests share the prefix at (varying) depth and decode long.
        let deep = shared_blocks(&hot[0].req.block_hashes, &hot[1].req.block_hashes);
        assert!(deep >= spec.prompt_len / BLOCK_TOKENS / 2, "shared depth {deep}");
        let hot_out = hot.iter().map(|r| r.req.output_len as u64).sum::<u64>() / hot.len() as u64;
        assert!(hot_out >= 16 * spec.output_len as u64 / 2, "sticky decodes");
    }

    #[test]
    fn spread_ctx_lands_in_the_requested_window() {
        let mut rng = Rng::new(21);
        for &(ks, ls) in &[(1.0, 1.0), (4.0, 8.0), (32.0, 16.0), (100.0, 4.0)] {
            let ctx = spread_route_ctx(&mut rng, 8, 4096, ks, ls);
            let kv: Vec<f64> = (0..8).map(|i| ctx.p_token(i) as f64).collect();
            let ld: Vec<f64> = (0..8).map(|i| (ctx.inds[i].bs() + 1) as f64).collect();
            let kmin = kv.iter().cloned().fold(f64::INFINITY, f64::min);
            let kmax = kv.iter().cloned().fold(0.0, f64::max);
            let lmin = ld.iter().cloned().fold(f64::INFINITY, f64::min);
            let lmax = ld.iter().cloned().fold(0.0, f64::max);
            assert!(kmin > 0.0);
            let got_ks = kmax / kmin;
            let got_ls = lmax / lmin;
            assert!(
                got_ks >= ks * 0.7 && got_ks <= ks * 1.5 + 1.0,
                "kv spread {got_ks} vs target {ks}"
            );
            assert!(
                got_ls >= ls * 0.6 && got_ls <= ls * 1.6 + 1.0,
                "load spread {got_ls} vs target {ls}"
            );
            // DES-plausible: block-aligned hits, queued implies a queued
            // batch member.
            for i in 0..8 {
                assert_eq!(ctx.hit_tokens[i] % BLOCK_TOKENS, 0);
                if ctx.inds[i].queued_prefill_tokens > 0 {
                    assert!(ctx.inds[i].q_bs > 0);
                }
            }
        }
    }

    #[test]
    fn degenerate_tie_ctx_ties_exactly_with_distinct_hits() {
        let mut rng = Rng::new(33);
        let mut saw_distinct = false;
        for _ in 0..20 {
            let ctx = degenerate_tie_ctx(&mut rng, 6, 1024);
            // All idle: the product reduces to P-token, which must tie.
            let scores: Vec<usize> = (0..6).map(|i| ctx.p_token(i)).collect();
            assert!(scores.iter().all(|&s| s == scores[0]), "products must tie");
            assert!(ctx.inds.iter().all(|d| d.bs() == 0), "all idle");
            if ctx.hit_tokens.iter().any(|&h| h != ctx.hit_tokens[0]) {
                saw_distinct = true;
            }
        }
        assert!(saw_distinct, "hits must differ so the tie-break matters");
    }
}
