//! Synthetic workload generators fitted to the paper's Fig 5 trace
//! characterization. Each family is a *session* process:
//!
//! * **ChatBot (Qwen)** — conversations: a class-shared system prompt,
//!   multi-turn history growth, human think-time gaps, moderate outputs.
//! * **Coder** — coding agents on a per-repo context: long prompts, high
//!   within-session reuse, machine-speed turn gaps, short outputs.
//! * **Agent (Qwen, API)** — API calling: short prompts, small shared
//!   system prompts, mostly single turns, bursty arrival.
//! * **ToolAgent (Kimi)** — agent loops: rapidly growing tool-result
//!   context, many quick turns, short outputs.
//! * **Hotspot** — the §5.2 adversarial case: background ChatBot traffic
//!   plus a burst window where one class with a long shared prefix takes
//!   a dominant share of arrivals while cached on few instances.
//!
//! Sessions make prefix reuse *structural*: turn k's prompt is exactly
//! turn k-1's prompt + the assistant reply + the new user span, so the
//! KV$ hit patterns (and the x/x̄ vs |M|/|M̄| hotspot ratios) emerge from
//! the workload rather than being injected.

use crate::core::Request;
use crate::tokenizer::{block_hashes, span};
use crate::util::rng::Zipf;
use crate::util::Rng;

use super::{clamp_len, Trace, TraceRequest};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    ChatBot,
    Coder,
    Agent,
    ToolAgent,
    Hotspot,
}

impl Workload {
    pub fn by_name(name: &str) -> Option<Workload> {
        Some(match name {
            "chatbot" => Workload::ChatBot,
            "coder" => Workload::Coder,
            "agent" | "api" => Workload::Agent,
            "toolagent" => Workload::ToolAgent,
            "hotspot" => Workload::Hotspot,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Workload::ChatBot => "chatbot",
            Workload::Coder => "coder",
            Workload::Agent => "agent",
            Workload::ToolAgent => "toolagent",
            Workload::Hotspot => "hotspot",
        }
    }
}

/// Distribution parameters of one workload family.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub workload: Workload,
    pub n_requests: usize,
    pub seed: u64,
    pub vocab: u32,
    /// Number of request classes (apps/users with shared system prompts).
    pub n_classes: usize,
    /// Zipf exponent of class popularity.
    pub class_skew: f64,
    /// Median system-prompt length (tokens).
    pub sys_prompt_median: f64,
    /// Median per-turn user-message length.
    pub user_span_median: f64,
    /// Median output length + log-sigma.
    pub output_median: f64,
    pub output_sigma: f64,
    /// Mean turns per session (geometric).
    pub mean_turns: f64,
    /// Mean think time between turns, seconds.
    pub turn_gap_s: f64,
    /// Session arrival rate, sessions/s (pre-scaling).
    pub session_rate: f64,
    /// Burstiness: every `burst_period_s`, arrivals speed up by
    /// `burst_factor` for `burst_len_s`.
    pub burst_period_s: f64,
    pub burst_len_s: f64,
    pub burst_factor: f64,
    /// Max prompt length (long-context guard).
    pub max_input: usize,
    /// Models multiplexed over the fleet. Each class is pinned to model
    /// `class_id % n_models` — an app talks to one model, and popular
    /// models serve many apps (the Zipf class skew induces a matching
    /// model skew for free). Derived with ZERO extra RNG draws, so
    /// `n_models = 1` (every request on the default model 0) leaves the
    /// whole sampled trace bit-identical to the pre-multiplexing one.
    pub n_models: usize,
}

impl WorkloadSpec {
    /// The per-family presets used throughout the benches.
    pub fn preset(workload: Workload, n_requests: usize, seed: u64) -> WorkloadSpec {
        let base = WorkloadSpec {
            workload,
            n_requests,
            seed,
            vocab: 50_000,
            n_classes: 12,
            class_skew: 1.1,
            sys_prompt_median: 400.0,
            user_span_median: 60.0,
            output_median: 250.0,
            output_sigma: 0.7,
            mean_turns: 4.0,
            turn_gap_s: 20.0,
            session_rate: 2.0,
            burst_period_s: 600.0,
            burst_len_s: 60.0,
            burst_factor: 1.4,
            max_input: 16_384,
            n_models: 1,
        };
        match workload {
            Workload::ChatBot | Workload::Hotspot => base,
            Workload::Coder => WorkloadSpec {
                n_classes: 8,
                class_skew: 0.9,
                sys_prompt_median: 2500.0,
                user_span_median: 150.0,
                output_median: 120.0,
                output_sigma: 0.6,
                mean_turns: 6.0,
                turn_gap_s: 5.0,
                session_rate: 1.0,
                ..base
            },
            Workload::Agent => WorkloadSpec {
                n_classes: 30,
                class_skew: 1.2,
                sys_prompt_median: 150.0,
                user_span_median: 80.0,
                output_median: 60.0,
                output_sigma: 0.6,
                mean_turns: 1.5,
                turn_gap_s: 3.0,
                session_rate: 6.0,
                burst_factor: 1.8,
                burst_period_s: 300.0,
                ..base
            },
            Workload::ToolAgent => WorkloadSpec {
                n_classes: 10,
                class_skew: 1.0,
                sys_prompt_median: 600.0,
                user_span_median: 300.0, // tool results are chunky
                output_median: 40.0,
                output_sigma: 0.5,
                mean_turns: 8.0,
                turn_gap_s: 2.0,
                session_rate: 1.5,
                ..base
            },
        }
    }

    /// Multiplex the workload over `n` models (builder-style; clamped to
    /// at least 1). See the `n_models` field for the class→model rule.
    pub fn with_n_models(mut self, n: usize) -> WorkloadSpec {
        self.n_models = n.max(1);
        self
    }
}

/// Generate a trace. Deterministic in (spec.workload, n_requests, seed).
///
/// NOTE: the turn-chain construction below (geometric turn count,
/// span-extend + truncate-at-max_input, assistant-extend) is
/// deliberately mirrored by [`super::sessions::generate_sessions`] —
/// this copy schedules arrivals open-loop, that one closed-loop. Keep
/// the turn-growth arithmetic in sync with
/// [`super::sessions::turn_growth`] (fuzzed out-of-band by
/// `python/tests/test_session_growth.py`); restructuring THIS function
/// would shift its RNG call order and silently re-seed every committed
/// figure.
pub fn generate(spec: &WorkloadSpec) -> Trace {
    let mut rng = Rng::new(spec.seed ^ (spec.workload as u64) << 48);
    let zipf = Zipf::new(spec.n_classes, spec.class_skew);
    let mut requests: Vec<TraceRequest> = Vec::with_capacity(spec.n_requests + 64);
    let mut next_id: u64 = 0;
    let mut session_ctr: u64 = 0;
    let mut clock_s: f64 = 0.0;

    while requests.len() < spec.n_requests {
        // --- session arrival (burst-modulated Poisson) ----------------
        let in_burst = (clock_s % spec.burst_period_s) < spec.burst_len_s;
        let rate = if in_burst {
            spec.session_rate * spec.burst_factor
        } else {
            spec.session_rate
        };
        clock_s += rng.exp(1.0 / rate);
        session_ctr += 1;
        let session = session_ctr;

        // --- class (hotspot workload overrides during its window) -----
        // The adversarial window covers the middle ~15% of the trace *by
        // request count*, so it survives arbitrary rate scaling.
        let progress = requests.len() as f64 / spec.n_requests as f64;
        let hot_window =
            spec.workload == Workload::Hotspot && (0.45..0.60).contains(&progress);
        // A pre-burst trickle keeps the class alive at low rate, so that
        // when the burst arrives its prefix is cached on only the one or
        // two instances that served the trickle (|M| small — the §5.2
        // precondition; a cold-start burst would scatter and self-dissipate).
        let trickle = spec.workload == Workload::Hotspot && rng.gen_bool(0.015);
        let class = if (hot_window && rng.gen_bool(0.85)) || trickle {
            // the adversarial "thinking workload" class
            (spec.n_classes) as u32 // one past the normal classes
        } else {
            zipf.sample(&mut rng) as u32
        };

        // --- build the session's turns --------------------------------
        let sys_len = clamp_len(
            rng.lognormal(
                if class as usize == spec.n_classes {
                    4000.0 // long shared prefix: the hotspot pattern
                } else {
                    spec.sys_prompt_median
                },
                0.3,
            ),
            32,
            spec.max_input / 2,
        );
        // geometric number of turns with mean `mean_turns`
        let p_stop = 1.0 / spec.mean_turns.max(1.0);
        let mut turns = 1;
        while !rng.gen_bool(p_stop) && turns < 40 {
            turns += 1;
        }
        if hot_window {
            turns = turns.min(2);
        }

        let mut prompt: Vec<u32> = span(class, 0, sys_len, spec.vocab);
        let mut t_s = clock_s;
        for turn in 0..turns {
            if requests.len() >= spec.n_requests {
                break;
            }
            // user span (fresh content, unique to this session+turn)
            let user_len = clamp_len(
                rng.lognormal(spec.user_span_median, 0.6),
                4,
                spec.max_input / 4,
            );
            prompt.extend(span(
                class,
                session * 10_000 + turn as u64 * 2 + 1,
                user_len,
                spec.vocab,
            ));
            if prompt.len() > spec.max_input {
                prompt.truncate(spec.max_input);
            }
            // The hotspot class is a "thinking" workload (§5.2's production
            // failure case): long shared prefix AND long outputs, so the
            // few instances caching the prefix saturate on decode.
            let out_median = if class as usize == spec.n_classes {
                1200.0
            } else {
                spec.output_median
            };
            let output_len =
                clamp_len(rng.lognormal(out_median, spec.output_sigma), 1, 4096) as u32;

            // Freeze the prompt into shared storage (the one copy every
            // later hop — router, queue, bookkeeping — will refcount).
            let tokens: std::sync::Arc<[u32]> = prompt.as_slice().into();
            let hashes = block_hashes(&tokens);
            // assistant reply tokens (deterministic: next turn reuses them)
            let assistant = span(
                class,
                session * 10_000 + turn as u64 * 2 + 2,
                output_len as usize,
                spec.vocab,
            );
            // next turn's prompt = this prompt + assistant (+ next user)
            prompt.extend(&assistant);
            let full_hashes = block_hashes(&prompt);

            requests.push(TraceRequest {
                req: Request {
                    id: next_id,
                    arrival_us: (t_s * 1e6) as u64,
                    class_id: class,
                    session_id: session,
                    // Pinned per class, no RNG draw: n_models = 1 keeps
                    // the trace bit-identical to pre-multiplexing.
                    model_id: class % spec.n_models.max(1) as u32,
                    tokens,
                    output_len,
                    block_hashes: hashes.into(),
                },
                full_hashes: full_hashes.into(),
            });
            next_id += 1;
            t_s += rng.exp(spec.turn_gap_s);
        }
    }

    requests.sort_by_key(|r| r.req.arrival_us);
    // Re-id in arrival order (stable ids for record joins).
    for (i, r) in requests.iter_mut().enumerate() {
        r.req.id = i as u64;
    }
    Trace {
        name: spec.workload.name().to_string(),
        requests,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tokenizer::shared_blocks;

    #[test]
    fn deterministic() {
        let a = generate(&WorkloadSpec::preset(Workload::ChatBot, 300, 7));
        let b = generate(&WorkloadSpec::preset(Workload::ChatBot, 300, 7));
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.req.tokens, y.req.tokens);
            assert_eq!(x.req.arrival_us, y.req.arrival_us);
        }
    }

    #[test]
    fn sorted_by_arrival() {
        let t = generate(&WorkloadSpec::preset(Workload::Agent, 400, 3));
        for w in t.requests.windows(2) {
            assert!(w[0].req.arrival_us <= w[1].req.arrival_us);
        }
        assert_eq!(t.requests.len(), 400);
    }

    #[test]
    fn session_turns_extend_previous_context() {
        let t = generate(&WorkloadSpec::preset(Workload::ToolAgent, 500, 5));
        // Find two requests of the same class where one's prompt extends
        // the other's full chain (a multi-turn continuation).
        let mut found = false;
        'outer: for (i, a) in t.requests.iter().enumerate() {
            for b in &t.requests[i + 1..] {
                if b.req.class_id == a.req.class_id
                    && b.req.block_hashes.len() > a.full_hashes.len()
                    && shared_blocks(&b.req.block_hashes, &a.full_hashes)
                        == a.full_hashes.len()
                {
                    found = true;
                    break 'outer;
                }
            }
        }
        assert!(found, "no continuation turns generated");
    }

    #[test]
    fn classes_share_system_prompt_blocks() {
        let t = generate(&WorkloadSpec::preset(Workload::ChatBot, 300, 11));
        let by_class: Vec<&TraceRequest> = t
            .requests
            .iter()
            .filter(|r| r.req.class_id == t.requests[0].req.class_id)
            .collect();
        assert!(by_class.len() >= 2);
        let s = shared_blocks(&by_class[0].req.block_hashes, &by_class[1].req.block_hashes);
        assert!(s >= 2, "same class must share the system prompt prefix");
    }

    #[test]
    fn family_shapes_differ_as_figure5() {
        let chat = generate(&WorkloadSpec::preset(Workload::ChatBot, 600, 1));
        let coder = generate(&WorkloadSpec::preset(Workload::Coder, 600, 1));
        let agent = generate(&WorkloadSpec::preset(Workload::Agent, 600, 1));
        let (chat_in, chat_out) = chat.token_stats();
        let (coder_in, coder_out) = coder.token_stats();
        let (agent_in, agent_out) = agent.token_stats();
        assert!(coder_in > chat_in, "coder prompts longest");
        assert!(agent_in < chat_in, "agent prompts shortest");
        assert!(chat_out > coder_out, "chat outputs longest");
        assert!(chat_out > agent_out);
    }

    #[test]
    fn hotspot_window_dominated_by_hot_class() {
        let spec = WorkloadSpec::preset(Workload::Hotspot, 4000, 9);
        let t = generate(&spec);
        let hot_class = spec.n_classes as u32;
        // The window is the middle of the trace by request index.
        let n = t.requests.len();
        let in_window = &t.requests[(n as f64 * 0.46) as usize..(n as f64 * 0.58) as usize];
        let hot = in_window.iter().filter(|r| r.req.class_id == hot_class).count();
        let share = hot as f64 / in_window.len() as f64;
        // Dominant burst: the hot class takes roughly half of the window's
        // arrivals (ongoing background sessions account for the rest).
        assert!(share > 0.4, "hot share {share}");
        // Outside the burst the class exists only as a low-rate trickle.
        let head = &t.requests[..(n as f64 * 0.35) as usize];
        let outside = head.iter().filter(|r| r.req.class_id == hot_class).count();
        assert!(
            (outside as f64) < head.len() as f64 * 0.12,
            "hot share outside window too high: {outside}/{}",
            head.len()
        );
        assert!(outside > 0, "trickle missing — burst would start cold");
    }

    #[test]
    fn outputs_at_least_one_token() {
        let t = generate(&WorkloadSpec::preset(Workload::Agent, 300, 2));
        assert!(t.requests.iter().all(|r| r.req.output_len >= 1));
    }

    #[test]
    fn model_ids_derive_from_class_without_shifting_the_rng() {
        let single = generate(&WorkloadSpec::preset(Workload::ChatBot, 400, 13));
        let multi =
            generate(&WorkloadSpec::preset(Workload::ChatBot, 400, 13).with_n_models(4));
        // Everything but the model id is bit-identical: the model mapping
        // consumed zero RNG draws.
        assert_eq!(single.requests.len(), multi.requests.len());
        for (a, b) in single.requests.iter().zip(&multi.requests) {
            assert_eq!(a.req.tokens, b.req.tokens);
            assert_eq!(a.req.arrival_us, b.req.arrival_us);
            assert_eq!(a.req.model_id, 0);
            assert_eq!(b.req.model_id, b.req.class_id % 4);
        }
        // A Zipf-skewed class mix reaches several models.
        let used: std::collections::HashSet<u32> =
            multi.requests.iter().map(|r| r.req.model_id).collect();
        assert!(used.len() >= 3, "models used: {used:?}");
    }
}
