//! Core types shared across the router, engines, traces and harnesses.

use std::sync::Arc;

/// Token-block granularity of the KV$ (vLLM-style prefix caching hashes
/// chains of fixed-size blocks; a prefix hit is a whole number of blocks).
pub const BLOCK_TOKENS: usize = 16;

/// Instance index within a cluster.
pub type InstanceId = usize;

/// A growable per-instance bit set (bit `i` = instance `i`). One `u64`
/// word per 64 instances, so clusters beyond 64 instances cost one extra
/// word per mask — never a bare-`u64` ceiling. Used by the shared prefix
/// index (which cached instances hold a block) and by [`crate::router`]'s
/// `RouteCtx` (which instances hold any prefix of the request — the
/// hotspot detector's M-set).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct InstanceMask {
    words: Vec<u64>,
}

impl InstanceMask {
    /// An all-zero mask sized for `n` instances.
    pub fn with_capacity(n: usize) -> Self {
        InstanceMask {
            words: vec![0; n.div_ceil(64)],
        }
    }

    /// Build from per-instance hit-token counts: bit `i` set iff
    /// `hit_tokens[i] > 0` (the M-set convention).
    pub fn from_hit_tokens(hit_tokens: &[usize]) -> Self {
        let mut m = InstanceMask::default();
        m.fill_from_hit_tokens(hit_tokens);
        m
    }

    /// In-place form of [`Self::from_hit_tokens`] — the single home of
    /// the M-set convention (bit `i` set iff `hit_tokens[i] > 0`).
    pub fn fill_from_hit_tokens(&mut self, hit_tokens: &[usize]) {
        self.reset(hit_tokens.len());
        for (i, &h) in hit_tokens.iter().enumerate() {
            if h > 0 {
                self.set(i);
            }
        }
    }

    /// Clear all bits and re-size the word array for `n` instances.
    pub fn reset(&mut self, n: usize) {
        self.words.clear();
        self.words.resize(n.div_ceil(64), 0);
    }

    pub fn set(&mut self, i: usize) {
        let w = i / 64;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    pub fn clear(&mut self, i: usize) {
        if let Some(w) = self.words.get_mut(i / 64) {
            *w &= !(1u64 << (i % 64));
        }
    }

    pub fn get(&self, i: usize) -> bool {
        self.words
            .get(i / 64)
            .map(|w| w & (1u64 << (i % 64)) != 0)
            .unwrap_or(false)
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Iterate set bit indices in ascending order.
    pub fn iter_ones(&self) -> MaskOnes<'_> {
        MaskOnes {
            words: &self.words,
            next_word: 0,
            base: 0,
            cur: 0,
        }
    }

    /// Re-size the mask for a fleet of `n` instances, PRESERVING the bits
    /// of instances that survive — the add/remove-instance primitive for
    /// fleet dynamics (scale-up/down, drain, crash). Growing zero-fills
    /// the new instances; shrinking drops every bit at index ≥ `n`, so a
    /// removed instance can never resurrect as a stale presence bit after
    /// a later grow re-uses its index.
    pub fn resize_instances(&mut self, n: usize) {
        let words = n.div_ceil(64);
        if words < self.words.len() {
            self.words.truncate(words);
        } else {
            self.words.resize(words, 0);
        }
        // Mask off the partial tail word: bits past `n` are gone NOW,
        // not whenever the word next gets rewritten.
        if let Some(last) = self.words.last_mut() {
            let rem = n % 64;
            if rem != 0 {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Raw word access (used by the shared prefix index walk).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Overwrite this mask's words from a raw slice (re-sizing as needed).
    pub fn copy_from_words(&mut self, words: &[u64]) {
        self.words.clear();
        self.words.extend_from_slice(words);
    }
}

/// Iterator over the set bits of an [`InstanceMask`].
pub struct MaskOnes<'a> {
    words: &'a [u64],
    next_word: usize,
    base: usize,
    cur: u64,
}

impl Iterator for MaskOnes<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            if self.next_word >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.next_word];
            self.base = self.next_word * 64;
            self.next_word += 1;
        }
        let b = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1;
        Some(self.base + b)
    }
}

/// A serving request as seen by the global scheduler.
///
/// Token and hash storage is `Arc`-shared: a request is cloned at every
/// hop of the harness (router bookkeeping, instance queue, completion
/// maps), and with `Vec` storage each hop re-copied the whole prompt.
/// `Arc<[T]>` makes `Request::clone` a couple of refcount bumps, so the
/// DES steady state performs zero per-request heap copies of token or
/// hash data — one allocation at trace build, shared forever after.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time in µs since trace start. For reactive session turns
    /// (see [`crate::trace::sessions`]) this is stamped by the DES at
    /// release time — completion of the previous turn plus think time.
    pub arrival_us: u64,
    /// Prefix-sharing class (≈ application/user: shared system prompt +
    /// conversation history). Drives KV$ hit structure and the §5.2
    /// hotspot analysis.
    pub class_id: u32,
    /// Session identity (0 = sessionless single-shot request). Turns of
    /// one conversation / agent loop share a session id; session-aware
    /// policies ([`crate::policy::StickySession`],
    /// [`crate::policy::SessionBalance`]) key their affinity state on it.
    pub session_id: u64,
    /// Model the request targets (0 = the fleet's default model, which
    /// every instance holds warm from the start). Multi-model traces
    /// multiplex several models over one fleet: serving a request whose
    /// model is cold on the chosen instance costs a profile-scaled weight
    /// swap (see [`crate::engine`]'s model slots).
    pub model_id: u32,
    /// Prompt token ids (shared, immutable after trace build).
    pub tokens: Arc<[u32]>,
    /// Number of output tokens the request will generate (from the trace;
    /// unknown to the scheduler a-priori, used by the engine only).
    pub output_len: u32,
    /// Chained block hashes of the prompt (see [`crate::tokenizer`]),
    /// computed once at ingest; used by every KV$ lookup (shared).
    pub block_hashes: Arc<[u64]>,
}

impl Request {
    pub fn input_len(&self) -> usize {
        self.tokens.len()
    }
}

/// Per-request latency record produced by a cluster run.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub id: u64,
    pub class_id: u32,
    pub instance: InstanceId,
    pub arrival_us: u64,
    pub first_token_us: u64,
    pub completion_us: u64,
    pub input_len: u32,
    pub output_len: u32,
    /// Prompt tokens served from KV$ (block-aligned).
    pub cached_tokens: u32,
}

impl RequestRecord {
    /// Time-to-first-token in seconds.
    pub fn ttft_s(&self) -> f64 {
        (self.first_token_us - self.arrival_us) as f64 / 1e6
    }

    /// Time-per-output-token in seconds (decode phase only).
    pub fn tpot_s(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        (self.completion_us - self.first_token_us) as f64
            / 1e6
            / (self.output_len - 1) as f64
    }

    /// KV$ hit ratio of the prompt.
    pub fn hit_ratio(&self) -> f64 {
        if self.input_len == 0 {
            0.0
        } else {
            self.cached_tokens as f64 / self.input_len as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> RequestRecord {
        RequestRecord {
            id: 1,
            class_id: 0,
            instance: 0,
            arrival_us: 1_000_000,
            first_token_us: 1_500_000,
            completion_us: 2_500_000,
            input_len: 100,
            output_len: 11,
            cached_tokens: 32,
        }
    }

    #[test]
    fn ttft_tpot() {
        let r = rec();
        assert!((r.ttft_s() - 0.5).abs() < 1e-12);
        assert!((r.tpot_s() - 0.1).abs() < 1e-12);
        assert!((r.hit_ratio() - 0.32).abs() < 1e-12);
    }

    #[test]
    fn tpot_single_token_zero() {
        let mut r = rec();
        r.output_len = 1;
        assert_eq!(r.tpot_s(), 0.0);
    }

    #[test]
    fn mask_set_get_clear() {
        let mut m = InstanceMask::with_capacity(4);
        assert!(m.is_empty());
        m.set(0);
        m.set(3);
        assert!(m.get(0) && m.get(3) && !m.get(1));
        assert_eq!(m.count(), 2);
        m.clear(0);
        assert!(!m.get(0));
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![3]);
    }

    #[test]
    fn mask_grows_past_64_instances() {
        let mut m = InstanceMask::with_capacity(1);
        m.set(130); // well past one word: must grow, not wrap
        assert!(m.get(130));
        assert!(!m.get(2)); // 130 % 64 == 2 — no aliasing across words
        assert!(!m.get(66));
        assert_eq!(m.count(), 1);
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![130]);
    }

    #[test]
    fn mask_from_hit_tokens_and_reset() {
        let mut m = InstanceMask::from_hit_tokens(&[0, 160, 0, 32]);
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![1, 3]);
        m.reset(2);
        assert!(m.is_empty());
        assert_eq!(m.words().len(), 1);
    }

    #[test]
    fn mask_out_of_range_get_is_false() {
        let m = InstanceMask::with_capacity(4);
        assert!(!m.get(1000));
    }

    #[test]
    fn mask_resize_instances_churn() {
        let mut m = InstanceMask::with_capacity(200);
        m.set(3);
        m.set(70);
        m.set(130);

        // Shrink to 100: instance 130 removed, survivors keep their bits.
        m.resize_instances(100);
        assert!(m.get(3) && m.get(70));
        assert!(!m.get(130));
        assert_eq!(m.words().len(), 2);

        // Shrink to exactly one word: 70 removed too.
        m.resize_instances(64);
        assert_eq!(m.words().len(), 1);
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![3]);

        // Grow back: removed instances must NOT resurrect.
        m.resize_instances(200);
        assert!(!m.get(70) && !m.get(130));
        assert_eq!(m.count(), 1);
        // New capacity is immediately usable.
        m.set(199);
        assert!(m.get(199));
        assert_eq!(m.iter_ones().collect::<Vec<_>>(), vec![3, 199]);

        // Shrink to a partial word: in-word tail bits past `n` are cleared
        // right away, not lazily on the next write.
        let mut p = InstanceMask::with_capacity(64);
        p.set(2);
        p.set(60);
        p.resize_instances(5);
        assert!(p.get(2));
        assert!(!p.get(60));
        assert_eq!(p.words(), &[0b100]);
        assert_eq!(p.count(), 1);
    }
}
