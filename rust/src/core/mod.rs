//! Core types shared across the router, engines, traces and harnesses.

/// Token-block granularity of the KV$ (vLLM-style prefix caching hashes
/// chains of fixed-size blocks; a prefix hit is a whole number of blocks).
pub const BLOCK_TOKENS: usize = 16;

/// Instance index within a cluster.
pub type InstanceId = usize;

/// A serving request as seen by the global scheduler.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time in µs since trace start.
    pub arrival_us: u64,
    /// Prefix-sharing class (≈ application/user: shared system prompt +
    /// conversation history). Drives KV$ hit structure and the §5.2
    /// hotspot analysis.
    pub class_id: u32,
    /// Prompt token ids.
    pub tokens: Vec<u32>,
    /// Number of output tokens the request will generate (from the trace;
    /// unknown to the scheduler a-priori, used by the engine only).
    pub output_len: u32,
    /// Chained block hashes of the prompt (see [`crate::tokenizer`]),
    /// computed once at ingest; used by every KV$ lookup.
    pub block_hashes: Vec<u64>,
}

impl Request {
    pub fn input_len(&self) -> usize {
        self.tokens.len()
    }
}

/// Per-request latency record produced by a cluster run.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub id: u64,
    pub class_id: u32,
    pub instance: InstanceId,
    pub arrival_us: u64,
    pub first_token_us: u64,
    pub completion_us: u64,
    pub input_len: u32,
    pub output_len: u32,
    /// Prompt tokens served from KV$ (block-aligned).
    pub cached_tokens: u32,
}

impl RequestRecord {
    /// Time-to-first-token in seconds.
    pub fn ttft_s(&self) -> f64 {
        (self.first_token_us - self.arrival_us) as f64 / 1e6
    }

    /// Time-per-output-token in seconds (decode phase only).
    pub fn tpot_s(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        (self.completion_us - self.first_token_us) as f64
            / 1e6
            / (self.output_len - 1) as f64
    }

    /// KV$ hit ratio of the prompt.
    pub fn hit_ratio(&self) -> f64 {
        if self.input_len == 0 {
            0.0
        } else {
            self.cached_tokens as f64 / self.input_len as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> RequestRecord {
        RequestRecord {
            id: 1,
            class_id: 0,
            instance: 0,
            arrival_us: 1_000_000,
            first_token_us: 1_500_000,
            completion_us: 2_500_000,
            input_len: 100,
            output_len: 11,
            cached_tokens: 32,
        }
    }

    #[test]
    fn ttft_tpot() {
        let r = rec();
        assert!((r.ttft_s() - 0.5).abs() < 1e-12);
        assert!((r.tpot_s() - 0.1).abs() < 1e-12);
        assert!((r.hit_ratio() - 0.32).abs() < 1e-12);
    }

    #[test]
    fn tpot_single_token_zero() {
        let mut r = rec();
        r.output_len = 1;
        assert_eq!(r.tpot_s(), 0.0);
    }
}
