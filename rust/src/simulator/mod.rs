//! VIDUR-like per-instance latency predictor — the substrate behind the
//! simulation-based baselines (llm-d §4.6, PolyServe §A.2).
//!
//! The predictor mirrors the engine's analytic cost model: given an
//! instance's current indicators and the request, it estimates the TTFT
//! (queued prefill ahead + own prefill + decode interference) and the
//! TPOT (step time with one more running sequence).
//!
//! Fidelity is a first-class *parameter*: the paper's Figs 15–16 study
//! what happens when the simulator is mis-tuned (built for another model)
//! — we reproduce that axis with (a) a wrong [`ModelProfile`] and (b) a
//! multiplicative log-normal error knob.

use crate::engine::ModelProfile;
use crate::router::{Indicators, RouteCtx};
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct LatencySimulator {
    /// The profile the simulator *believes* (tuned = the engine's actual
    /// profile; untuned = another model's).
    pub profile: ModelProfile,
    pub chunk_budget: usize,
    /// Multiplicative log-normal error sigma (0 = deterministic).
    pub noise_sigma: f64,
    rng: Rng,
}

impl LatencySimulator {
    /// A well-tuned simulator for the given engine profile.
    pub fn tuned(profile: ModelProfile, chunk_budget: usize) -> Self {
        LatencySimulator {
            profile,
            chunk_budget,
            noise_sigma: 0.0,
            rng: Rng::new(0x51a7),
        }
    }

    /// A mis-tuned simulator: wrong model profile + heavy residual noise
    /// (the paper's "originally used for another model" setup, Fig 15).
    /// A purely systematic (multiplicative) profile error would cancel
    /// under cross-instance comparison; what actually breaks routing is
    /// the *per-prediction* error an unfitted simulator makes — Fig 16
    /// shows ~uniform error ratios reaching 100%, which σ=0.8 log-normal
    /// noise reproduces.
    pub fn untuned(wrong_profile: ModelProfile, chunk_budget: usize) -> Self {
        LatencySimulator {
            profile: wrong_profile,
            chunk_budget,
            noise_sigma: 0.8,
            rng: Rng::new(0x0bad),
        }
    }

    fn noise(&mut self) -> f64 {
        if self.noise_sigma == 0.0 {
            1.0
        } else {
            (self.noise_sigma * self.rng.normal()).exp()
        }
    }

    /// Predicted TTFT (µs) if the request is routed to instance `i`.
    pub fn predict_ttft(&mut self, ctx: &RouteCtx, i: usize) -> f64 {
        let ind = &ctx.inds[i];
        let new = ctx.new_tokens(i);
        let hit = ctx.hit_tokens[i];
        let p = &self.profile;
        // Work queued ahead of us (other requests' unprefillied tokens).
        let queue_us = if ind.queued_prefill_tokens > 0 {
            p.prefill_us(ind.queued_prefill_tokens, 0, self.chunk_budget)
        } else {
            0.0
        };
        // Our own prefill, starting from the cached context.
        let own_us = p.prefill_us(new, hit, self.chunk_budget);
        // Decode interference: each prefill step also carries the running
        // batch's decode work.
        let steps = ((ind.queued_prefill_tokens + new + self.chunk_budget - 1)
            / self.chunk_budget)
            .max(1);
        let decode_per_step = if ind.r_bs > 0 {
            p.decode_base_us
                + ind.r_bs as f64 * p.decode_us_per_seq
                + ind.total_context_tokens as f64 * p.decode_us_per_kv_token
        } else {
            0.0
        };
        (queue_us + own_us + steps as f64 * decode_per_step) * self.noise()
    }

    /// Predicted steady-state TPOT (µs/token) on instance `i` with this
    /// request added to the running batch.
    pub fn predict_tpot(&mut self, ind: &Indicators, added_ctx: usize) -> f64 {
        let p = &self.profile;
        let seqs = ind.bs() + 1;
        let ctx = ind.total_context_tokens + added_ctx;
        (p.step_fixed_us
            + p.decode_base_us
            + seqs as f64 * p.decode_us_per_seq
            + ctx as f64 * p.decode_us_per_kv_token)
            * self.noise()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::router::Indicators;

    fn ctx_with(inds: Vec<Indicators>, hits: Vec<usize>, input: usize) -> RouteCtx {
        RouteCtx::new(0, 0, 0, input, hits, inds)
    }

    #[test]
    fn hit_lowers_predicted_ttft() {
        let mut sim = LatencySimulator::tuned(ModelProfile::moe_30b(), 256);
        let ctx = ctx_with(
            vec![Indicators::default(), Indicators::default()],
            vec![0, 1024],
            2048,
        );
        let cold = sim.predict_ttft(&ctx, 0);
        let warm = sim.predict_ttft(&ctx, 1);
        assert!(warm < cold * 0.7, "cold={cold} warm={warm}");
    }

    #[test]
    fn queue_raises_predicted_ttft() {
        let mut sim = LatencySimulator::tuned(ModelProfile::moe_30b(), 256);
        let mut busy = Indicators::default();
        busy.queued_prefill_tokens = 4000;
        let ctx = ctx_with(vec![Indicators::default(), busy], vec![0, 0], 512);
        assert!(sim.predict_ttft(&ctx, 1) > sim.predict_ttft(&ctx, 0) * 2.0);
    }

    #[test]
    fn tpot_grows_with_batch() {
        let mut sim = LatencySimulator::tuned(ModelProfile::moe_30b(), 256);
        let small = Indicators::default();
        let mut big = Indicators::default();
        big.r_bs = 32;
        big.total_context_tokens = 32 * 800;
        assert!(sim.predict_tpot(&big, 512) > sim.predict_tpot(&small, 512));
    }

    #[test]
    fn untuned_is_noisy_and_biased() {
        // Engine truth: moe-30b. Untuned sim believes dense-7b.
        let mut tuned = LatencySimulator::tuned(ModelProfile::moe_30b(), 256);
        let mut untuned = LatencySimulator::untuned(ModelProfile::dense_7b(), 256);
        let ctx = ctx_with(vec![Indicators::default()], vec![0], 2048);
        let t = tuned.predict_ttft(&ctx, 0);
        let samples: Vec<f64> = (0..50).map(|_| untuned.predict_ttft(&ctx, 0)).collect();
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        // dense-7b per-token cost is ~2x moe-30b: systematic bias.
        assert!((mean - t).abs() / t > 0.3);
        // And noisy: spread across calls.
        let spread = samples.iter().cloned().fold(f64::MIN, f64::max)
            / samples.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 1.3);
    }

    #[test]
    fn deterministic_when_noiseless() {
        let mut sim = LatencySimulator::tuned(ModelProfile::moe_30b(), 256);
        let ctx = ctx_with(vec![Indicators::default()], vec![0], 1000);
        assert_eq!(sim.predict_ttft(&ctx, 0), sim.predict_ttft(&ctx, 0));
    }
}
