//! # LMetric — multiplicative LLM request scheduling
//!
//! A from-scratch reproduction of *"Simple is Better: Multiplication May Be
//! All You Need for LLM Request Scheduling"*: a Rust global scheduler
//! (router) for a cluster of PD-colocated LLM serving instances, plus every
//! substrate the paper's evaluation depends on.
//!
//! The headline policy is [`policy::LMetric`]: route each request to the
//! instance minimizing `P-token × BS`, where `P-token` is the number of new
//! prefill tokens if routed there (queued prefill tokens + prompt tokens
//! missing from that instance's KV$) and `BS` the instance batch size. No
//! hyperparameters — the linear combination's weights cancel under
//! comparison (§5 of the paper).
//!
//! Layout (three layers; Python never on the request path):
//! * [`router`] + [`policy`] — the paper's contribution: indicator factory
//!   and the scheduling policies studied in the paper, plus session-aware
//!   baselines (`sticky`, `smetric`).
//! * [`engine`] — a vLLM-v1-like instance: continuous batching, chunked
//!   prefill, radix-tree KV$, analytic step cost model.
//! * [`cluster`] — a discrete-event simulation harness (virtual time, used
//!   by all figure benches) and a live threaded cluster (wall-clock time,
//!   real transformer compute through [`runtime`]).
//! * [`runtime`] — loads the AOT HLO artifacts produced by
//!   `python/compile/aot.py` and executes them on the PJRT CPU client.
//! * [`trace`] — synthetic workload generators matching the paper's four
//!   trace families, plus replayer, rate scaling, the adversarial
//!   failure-regime generators ([`trace::adversarial`]) and the
//!   closed-loop session engine ([`trace::sessions`], replayed
//!   reactively by [`cluster`]'s `run_session_des`).
//! * [`hotspot`] — the §5.2 two-phase KV$-hotspot detector.
//! * [`policy::GuardedLMetric`] — the failure-condition guard
//!   (`lmetric_safe`): detects the derived degenerate / cross-spread
//!   misranking regimes per decision and re-ranks degenerate ties.
//! * [`simulator`] — the VIDUR-like latency predictor used by the
//!   simulation-based baselines (llm-d, PolyServe).

pub mod benchlib;
pub mod cluster;
pub mod config;
pub mod core;
pub mod engine;
pub mod hotspot;
pub mod kvcache;
pub mod metrics;
pub mod policy;
pub mod router;
pub mod runtime;
pub mod simulator;
pub mod tokenizer;
pub mod trace;
pub mod util;
