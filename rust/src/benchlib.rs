//! Micro-bench harness for the `harness = false` bench binaries (criterion
//! is unavailable offline — see DESIGN.md §1). Provides warmup + timed
//! iterations with mean/p50/p99 reporting, and a figure-bench runner that
//! standardizes stdout headers across the fig*_ benches.

use std::time::Instant;

/// Timing result of a micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns)
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Run `f` repeatedly: warm up briefly, then time `iters` iterations
/// (capped at ~2 s of wall time).
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    // Warmup.
    let warm = (iters / 10).clamp(1, 100);
    for _ in 0..warm {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let budget = std::time::Duration::from_secs(2);
    let start = Instant::now();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed() > budget {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: samples.iter().sum::<f64>() / n as f64,
        p50_ns: samples[n / 2],
        p99_ns: samples[((n as f64 * 0.99) as usize).min(n - 1)],
    }
}

/// Standard banner for figure benches.
pub fn figure_banner(fig: &str, what: &str) {
    println!("\n================================================================");
    println!("{fig}: {what}");
    println!("================================================================");
}

/// `--quick` support: figure benches downscale request counts under
/// `LMETRIC_BENCH_QUICK=1` (used by CI-style smoke runs).
pub fn quick_mode() -> bool {
    std::env::var("LMETRIC_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

// ---------------------------------------------------------------------
// Parallel sweep runner: deterministic fan-out of independent
// (policy × sweep-point) DES runs across worker threads.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count for [`parallel_sweep`]: `LMETRIC_BENCH_THREADS` when set
/// (`=1` forces fully serial execution — the debugging escape hatch),
/// otherwise `available_parallelism`. An unparsable value panics rather
/// than silently degrading to serial (a typo'd var would otherwise be
/// indistinguishable from a deliberate serial run in the bench JSON);
/// set-but-empty counts as unset.
pub fn bench_threads() -> usize {
    match std::env::var("LMETRIC_BENCH_THREADS") {
        Ok(v) if !v.trim().is_empty() => match v.trim().parse::<usize>() {
            Ok(n) => n.max(1),
            Err(_) => panic!("LMETRIC_BENCH_THREADS must be a positive integer, got {v:?}"),
        },
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Run `f` over every item of `items` across [`bench_threads`] scoped
/// worker threads (no extra dependencies — `std::thread::scope`),
/// returning results **in input order**.
///
/// Jobs are claimed from a shared atomic counter, so scheduling is
/// work-stealing-ish, but since every job is a pure function of its item
/// (each DES run owns its instances, policy and metrics; traces are
/// borrowed immutably) the results are bit-identical to a serial run —
/// only wall-clock changes. With one thread (or one item) it degrades to
/// a plain in-place loop, so `LMETRIC_BENCH_THREADS=1` reproduces the
/// historical serial behaviour exactly.
pub fn parallel_sweep<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = bench_threads().min(items.len());
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        done.push((i, f(i, &items[i])));
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("sweep worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|r| r.expect("sweep job skipped")).collect()
}

/// Scale a request count down in quick mode.
pub fn scaled(n: usize) -> usize {
    if quick_mode() {
        (n / 10).max(200)
    } else {
        n
    }
}

// ---------------------------------------------------------------------
// Figure-bench experiment helpers (shared by every rust/benches/fig*.rs).

use crate::cluster::{build_scaled_trace, cluster_config, run_des};
use crate::config::ExperimentConfig;
use crate::engine::ModelProfile;
use crate::metrics::{ResultRow, RunMetrics};
use crate::policy;
use crate::router::{IndicatorFactory, Policy, RouteCtx};
use crate::trace::{Trace, TraceRequest};

/// Fraction of the run discarded as cold-start warm-up.
pub const WARMUP: f64 = 0.1;

/// The standard §6 experiment: `workload` on `instances`×moe-30b at
/// `rate_scale`× profiled capacity.
pub fn experiment(workload: &str, instances: usize, requests: usize) -> ExperimentConfig {
    let mut exp = ExperimentConfig::default();
    exp.workload = workload.into();
    exp.instances = instances;
    exp.requests = scaled(requests);
    exp
}

/// Run one policy (by name, with an explicit hyperparameter) on a shared
/// trace; warm-up discarded.
pub fn run_policy(
    exp: &ExperimentConfig,
    trace: &Trace,
    name: &str,
    param: f64,
) -> (RunMetrics, String) {
    let cfg = cluster_config(exp);
    let mut pol = policy::build(name, param, &cfg.engine.profile, exp.chunk_budget)
        .unwrap_or_else(|e| panic!("{e}"));
    let mut m = run_des(&cfg, trace, pol.as_mut());
    m.discard_warmup(WARMUP);
    (m, pol.name())
}

/// Run with a caller-constructed policy (for stateful inspection).
pub fn run_boxed(
    exp: &ExperimentConfig,
    trace: &Trace,
    pol: &mut dyn Policy,
) -> RunMetrics {
    let cfg = cluster_config(exp);
    let mut m = run_des(&cfg, trace, pol);
    m.discard_warmup(WARMUP);
    m
}

/// Run one policy at its paper-default hyperparameter.
pub fn run_default(exp: &ExperimentConfig, trace: &Trace, name: &str) -> (RunMetrics, String) {
    run_policy(exp, trace, name, policy::default_param(name))
}

/// Build the experiment's scaled trace (shared across policies so every
/// row sees identical arrivals).
pub fn trace_for(exp: &ExperimentConfig) -> Trace {
    build_scaled_trace(exp)
}

/// Standard result row from a run.
pub fn row(label: &str, m: &RunMetrics) -> ResultRow {
    ResultRow::from_metrics(label, m)
}

/// Score `probes` across `r` scoped workers against a frozen factory
/// (read-only [`IndicatorFactory::fill_route_ctx`] + `lmetric` policy
/// scoring, no commits), returning decisions/s. Mirrors the concurrent
/// DES harness's scoring phase — worker-owned ctx + policy replica,
/// `k % r` assignment — without the DES around it, so the number
/// isolates pure read-path scaling. Shared by `fig61_router_scale` and
/// the `router_throughput` perf-trajectory bench.
pub fn decision_rate(
    factory: &IndicatorFactory,
    profile: &ModelProfile,
    probes: &[TraceRequest],
    r: usize,
) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for w in 0..r {
            scope.spawn(move || {
                let mut pol = policy::build_default("lmetric", profile, 256).unwrap();
                let mut ctx = RouteCtx::default();
                let mut live: Vec<u64> = Vec::new();
                for (k, tr) in probes.iter().enumerate() {
                    if k % r == w {
                        factory.fill_route_ctx(&tr.req, tr.req.arrival_us, &mut ctx, &mut live);
                        std::hint::black_box(pol.route(&ctx).instance);
                    }
                }
            });
        }
    });
    probes.len() as f64 / t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut x = 0u64;
        let r = bench("noop", 100, || {
            x = x.wrapping_add(1);
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert!(fmt_ns(1500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.0e9).contains("s"));
    }

    #[test]
    fn sweep_returns_results_in_input_order() {
        let items: Vec<usize> = (0..97).collect();
        let out = parallel_sweep(&items, |i, &x| {
            assert_eq!(i, x);
            x * 3 + 1
        });
        assert_eq!(out, items.iter().map(|x| x * 3 + 1).collect::<Vec<_>>());
        // Empty input is a no-op.
        let empty: Vec<usize> = Vec::new();
        assert!(parallel_sweep(&empty, |_, &x| x).is_empty());
    }

    /// Determinism across execution modes: a parallel fan-out of DES runs
    /// must produce record-for-record identical results to the serial
    /// path (parallelism may only change wall-clock, never virtual time).
    #[test]
    fn sweep_des_runs_match_serial() {
        let mut exp = ExperimentConfig::default();
        exp.instances = 2;
        exp.requests = 120;
        exp.rate_scale = 0.5;
        let trace = build_scaled_trace(&exp);
        let jobs = ["vllm", "lmetric", "linear"];
        let run = |name: &str| -> Vec<(u64, u64, usize)> {
            let (m, _) = run_policy(&exp, &trace, name, policy::default_param(name));
            m.records.iter().map(|r| (r.id, r.completion_us, r.instance)).collect()
        };
        let par = parallel_sweep(&jobs, |_, name| run(name));
        let ser: Vec<_> = jobs.iter().map(|name| run(name)).collect();
        assert_eq!(par, ser);
    }
}
