//! Experiment configuration: a typed config with a TOML-subset file format
//! (sections, `key = value`, comments) so runs are launchable as
//! `lmetric replay --config exp.toml` — the "real config system" a
//! deployable framework needs.

use std::collections::BTreeMap;

use crate::engine::InstanceProfile;

/// Typed fleet composition: an ordered list of (hardware class, count)
/// runs. Instance `i` belongs to the class whose cumulative count first
/// covers `i`, so `"h100:2,l40:6"` means slots 0–1 are H100-class and
/// 2–7 are L40-class.
///
/// [`FleetSpec::uniform`] is the compatibility point: it produces `n`
/// reference-class slots, and every consumer (engine build, router
/// indicator factory, DES/live/concurrent clusters) branches on
/// [`InstanceProfile::is_reference`] back onto the exact pre-fleet code
/// path — a uniform spec replays byte-identical to the scalar
/// `instances` config it replaces (pinned by `cluster::des` tests).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    classes: Vec<(InstanceProfile, usize)>,
}

impl FleetSpec {
    /// `n` reference-class slots — what the deprecated scalar `instances`
    /// field desugars to.
    pub fn uniform(n: usize) -> FleetSpec {
        FleetSpec {
            classes: vec![(InstanceProfile::reference(), n)],
        }
    }

    /// Append `count` slots of `profile` (builder-style).
    pub fn with_class(mut self, profile: InstanceProfile, count: usize) -> FleetSpec {
        self.classes.push((profile, count));
        self
    }

    /// An empty spec to build on with [`Self::with_class`].
    pub fn empty() -> FleetSpec {
        FleetSpec { classes: Vec::new() }
    }

    pub fn n_instances(&self) -> usize {
        self.classes.iter().map(|(_, c)| c).sum()
    }

    /// The class of slot `i`. Indices past the declared fleet (scale-ups
    /// widening the fleet at runtime) inherit the last class, so a
    /// uniform fleet stays uniform under scale-up.
    pub fn profile_for(&self, i: usize) -> &InstanceProfile {
        let mut seen = 0usize;
        for (p, count) in &self.classes {
            seen += count;
            if i < seen {
                return p;
            }
        }
        &self
            .classes
            .last()
            .expect("FleetSpec must declare at least one class")
            .0
    }

    /// True iff every slot is the reference class — the byte-identity
    /// fast-path predicate.
    pub fn is_uniform(&self) -> bool {
        self.classes.iter().all(|(p, _)| p.is_reference())
    }

    /// Parse the `"class:count,class:count"` form used by the TOML
    /// `[fleet] spec` key and the `--fleet` CLI flag. Unknown class names
    /// fail with the class listing.
    pub fn parse(spec: &str) -> Result<FleetSpec, String> {
        let mut fleet = FleetSpec::empty();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (class, count) = part
                .split_once(':')
                .ok_or_else(|| format!("fleet spec '{part}': expected class:count"))?;
            let profile = InstanceProfile::by_name(class.trim()).ok_or_else(|| {
                format!(
                    "unknown instance class '{}'; valid classes: {}",
                    class.trim(),
                    InstanceProfile::all_class_names().join(", ")
                )
            })?;
            let count: usize = count
                .trim()
                .parse()
                .map_err(|_| format!("fleet spec '{part}': count must be an integer"))?;
            if count == 0 {
                return Err(format!("fleet spec '{part}': count must be >= 1"));
            }
            fleet = fleet.with_class(profile, count);
        }
        if fleet.classes.is_empty() {
            return Err("fleet spec declares no instances".to_string());
        }
        Ok(fleet)
    }

    /// The canonical `"class:count,…"` rendering (round-trips
    /// [`Self::parse`]).
    pub fn summary(&self) -> String {
        self.classes
            .iter()
            .map(|(p, c)| format!("{}:{c}", p.class))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// The declared (class, count) runs.
    pub fn classes(&self) -> &[(InstanceProfile, usize)] {
        &self.classes
    }
}

/// Parsed `[section] key = value` document. Values keep their raw string;
/// typed accessors parse on demand.
#[derive(Debug, Default, Clone)]
pub struct ConfigDoc {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc, String> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(doc)
    }

    pub fn from_file(path: &str) -> Result<ConfigDoc, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        ConfigDoc::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Option<u64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            "true" | "1" | "yes" => Some(true),
            "false" | "0" | "no" => Some(false),
            _ => None,
        }
    }
}

/// Top-level experiment description: which trace, which cluster, which
/// policy. Every bench and CLI subcommand builds one of these.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// **Deprecated shim** — the scalar fleet size, kept because every
    /// pre-fleet bench and config sets it. It desugars to
    /// [`FleetSpec::uniform`]`(instances)` (pinned byte-identical by
    /// `cluster::des` tests) whenever [`Self::fleet`] is `None`. New code
    /// should set `fleet` (TOML `[fleet] spec`, CLI `--fleet`) instead.
    pub instances: usize,
    /// Heterogeneous fleet composition; `None` = uniform reference fleet
    /// of `instances` slots (see [`Self::effective_fleet`]).
    pub fleet: Option<FleetSpec>,
    pub profile: String,
    pub kv_capacity_blocks: usize,
    pub chunk_budget: usize,
    pub max_batch: usize,
    pub workload: String,
    pub requests: usize,
    pub seed: u64,
    /// Average arrival rate as a fraction of profiled cluster capacity
    /// (§4.1 trace scaling; the paper uses 0.5).
    pub rate_scale: f64,
    pub policy: String,
    /// Policy hyperparameter (λ for linear, Range for filter, T for
    /// Preble, τ-SLO for PolyServe...).
    pub param: f64,
    /// Within-instance queue ordering (`engine::queue` name:
    /// fcfs / srpt / ltr).
    pub queue_policy: String,
    /// Distinct models multiplexed by the trace (1 = single-model; the
    /// trace assigns `model_id = class_id % n_models`, which draws zero
    /// RNG values so committed single-model traces replay unchanged).
    pub n_models: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            instances: 16,
            fleet: None,
            profile: "moe-30b".into(),
            kv_capacity_blocks: 8192,
            chunk_budget: 256,
            max_batch: 64,
            workload: "chatbot".into(),
            requests: 4000,
            seed: 42,
            rate_scale: 0.5,
            policy: "lmetric".into(),
            param: 0.7,
            queue_policy: "fcfs".into(),
            n_models: 1,
        }
    }
}

impl ExperimentConfig {
    /// The fleet this experiment runs on: the typed spec when one was
    /// given, else the deprecated scalar desugared to a uniform fleet.
    pub fn effective_fleet(&self) -> FleetSpec {
        self.fleet
            .clone()
            .unwrap_or_else(|| FleetSpec::uniform(self.instances))
    }

    /// Build from a parsed document, validating the invariants the
    /// engine cannot express: `chunk_budget == 0` livelocks a busy
    /// instance (the engine debug-asserts; here it is a proper error),
    /// and queue-policy names must exist in the `engine::queue` registry
    /// so typos surface as the name-listing error, not a panic.
    pub fn from_doc(doc: &ConfigDoc) -> Result<ExperimentConfig, String> {
        let mut c = ExperimentConfig::default();
        if let Some(v) = doc.get_usize("cluster", "instances") {
            c.instances = v;
        }
        if let Some(v) = doc.get("cluster", "profile") {
            c.profile = v.to_string();
        }
        if let Some(v) = doc.get_usize("cluster", "kv_capacity_blocks") {
            c.kv_capacity_blocks = v;
        }
        if let Some(v) = doc.get_usize("cluster", "chunk_budget") {
            c.chunk_budget = v;
        }
        if let Some(v) = doc.get_usize("cluster", "max_batch") {
            c.max_batch = v;
        }
        if let Some(v) = doc.get("cluster", "queue_policy") {
            c.queue_policy = v.to_string();
        }
        if let Some(v) = doc.get("trace", "workload") {
            c.workload = v.to_string();
        }
        if let Some(v) = doc.get_usize("trace", "requests") {
            c.requests = v;
        }
        if let Some(v) = doc.get_u64("trace", "seed") {
            c.seed = v;
        }
        if let Some(v) = doc.get_f64("trace", "rate_scale") {
            c.rate_scale = v;
        }
        if let Some(v) = doc.get_usize("trace", "n_models") {
            c.n_models = v.max(1);
        }
        if let Some(v) = doc.get("fleet", "spec") {
            let fleet = FleetSpec::parse(v)?;
            // Keep the deprecated scalar coherent with the typed spec so
            // pre-fleet readers (benches, usage text) see the right size.
            c.instances = fleet.n_instances();
            c.fleet = Some(fleet);
        }
        if let Some(v) = doc.get("policy", "name") {
            c.policy = v.to_string();
        }
        if let Some(v) = doc.get_f64("policy", "param") {
            c.param = v;
        }
        if c.chunk_budget == 0 {
            return Err(
                "cluster.chunk_budget must be >= 1 (a zero budget livelocks a busy \
                 instance: running sequences can never be stepped)"
                    .to_string(),
            );
        }
        // Surface unknown queue-policy names here with the registry's
        // name-listing error rather than panicking at Instance::new.
        crate::engine::queue::build(&c.queue_policy)?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment
[cluster]
instances = 8
profile = "dense-7b"   # dense model
kv_capacity_blocks = 4096

[trace]
workload = "coder"
requests = 100
rate_scale = 0.75

[policy]
name = "linear"
param = 0.55
"#;

    #[test]
    fn parse_sections_and_comments() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("cluster", "profile"), Some("dense-7b"));
        assert_eq!(doc.get_usize("cluster", "instances"), Some(8));
        assert_eq!(doc.get_f64("policy", "param"), Some(0.55));
        assert_eq!(doc.get("nope", "x"), None);
    }

    #[test]
    fn experiment_from_doc_overrides_defaults() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.instances, 8);
        assert_eq!(c.workload, "coder");
        assert_eq!(c.policy, "linear");
        assert_eq!(c.param, 0.55);
        // untouched defaults:
        assert_eq!(c.chunk_budget, 256);
        assert_eq!(c.queue_policy, "fcfs");
    }

    #[test]
    fn experiment_from_doc_reads_queue_policy() {
        let doc = ConfigDoc::parse("[cluster]\nqueue_policy = \"srpt\"").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.queue_policy, "srpt");
    }

    /// Regression (livelock bugfix): the pre-fix config accepted
    /// `chunk_budget = 0` and handed the DES an engine that could never
    /// step a busy instance. It must now be a build-time error.
    #[test]
    fn experiment_from_doc_rejects_zero_chunk_budget() {
        let doc = ConfigDoc::parse("[cluster]\nchunk_budget = 0").unwrap();
        let err = ExperimentConfig::from_doc(&doc).err().unwrap();
        assert!(err.contains("chunk_budget"), "error names the field: {err}");
    }

    #[test]
    fn experiment_from_doc_rejects_unknown_queue_policy_with_listing() {
        let doc = ConfigDoc::parse("[cluster]\nqueue_policy = \"sjf\"").unwrap();
        let err = ExperimentConfig::from_doc(&doc).err().unwrap();
        assert!(err.contains("sjf"), "error names the input: {err}");
        for name in crate::engine::queue::all_names() {
            assert!(err.contains(name), "error lists '{name}': {err}");
        }
    }

    #[test]
    fn bad_line_is_error() {
        assert!(ConfigDoc::parse("[a]\nnot a kv line").is_err());
    }

    #[test]
    fn bools() {
        let doc = ConfigDoc::parse("[s]\na = true\nb = no").unwrap();
        assert_eq!(doc.get_bool("s", "a"), Some(true));
        assert_eq!(doc.get_bool("s", "b"), Some(false));
    }

    #[test]
    fn fleet_spec_parses_and_maps_slots_to_classes() {
        let f = FleetSpec::parse("h100:2, l40:6").unwrap();
        assert_eq!(f.n_instances(), 8);
        assert!(!f.is_uniform());
        assert_eq!(f.profile_for(0).class, "h100");
        assert_eq!(f.profile_for(1).class, "h100");
        assert_eq!(f.profile_for(2).class, "l40");
        assert_eq!(f.profile_for(7).class, "l40");
        // Scale-ups past the declared fleet inherit the last class.
        assert_eq!(f.profile_for(20).class, "l40");
        assert_eq!(f.summary(), "h100:2,l40:6");
        assert_eq!(FleetSpec::parse(&f.summary()).unwrap(), f);
    }

    #[test]
    fn fleet_spec_uniform_matches_the_scalar_shim() {
        let f = FleetSpec::uniform(16);
        assert!(f.is_uniform());
        assert_eq!(f.n_instances(), 16);
        assert!(f.profile_for(0).is_reference());
        assert!(f.profile_for(99).is_reference());
        // The deprecated scalar desugars to exactly this.
        let exp = ExperimentConfig::default();
        assert_eq!(exp.effective_fleet(), FleetSpec::uniform(exp.instances));
        assert_eq!(FleetSpec::parse("default:16").unwrap().n_instances(), 16);
    }

    #[test]
    fn fleet_spec_rejects_bad_input_with_class_listing() {
        let err = FleetSpec::parse("tpu9:4").err().unwrap();
        assert!(err.contains("tpu9"), "{err}");
        for name in crate::engine::InstanceProfile::all_class_names() {
            assert!(err.contains(name), "error lists '{name}': {err}");
        }
        assert!(FleetSpec::parse("h100").is_err(), "missing count");
        assert!(FleetSpec::parse("h100:x").is_err(), "bad count");
        assert!(FleetSpec::parse("h100:0").is_err(), "zero count");
        assert!(FleetSpec::parse("").is_err(), "empty spec");
    }

    #[test]
    fn experiment_from_doc_reads_fleet_table() {
        let doc =
            ConfigDoc::parse("[fleet]\nspec = \"h100:2,l40:2\"\n[trace]\nn_models = 3").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        let fleet = c.fleet.clone().unwrap();
        assert_eq!(fleet.n_instances(), 4);
        assert_eq!(c.instances, 4, "scalar shim tracks the typed spec");
        assert_eq!(c.n_models, 3);
        assert_eq!(c.effective_fleet(), fleet);
        // Unknown classes surface the listing error at config build.
        let bad = ConfigDoc::parse("[fleet]\nspec = \"warp:1\"").unwrap();
        assert!(ExperimentConfig::from_doc(&bad).is_err());
    }
}
