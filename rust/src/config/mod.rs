//! Experiment configuration: a typed config with a TOML-subset file format
//! (sections, `key = value`, comments) so runs are launchable as
//! `lmetric replay --config exp.toml` — the "real config system" a
//! deployable framework needs.

use std::collections::BTreeMap;

/// Parsed `[section] key = value` document. Values keep their raw string;
/// typed accessors parse on demand.
#[derive(Debug, Default, Clone)]
pub struct ConfigDoc {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<ConfigDoc, String> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(doc)
    }

    pub fn from_file(path: &str) -> Result<ConfigDoc, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        ConfigDoc::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Option<u64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            "true" | "1" | "yes" => Some(true),
            "false" | "0" | "no" => Some(false),
            _ => None,
        }
    }
}

/// Top-level experiment description: which trace, which cluster, which
/// policy. Every bench and CLI subcommand builds one of these.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub instances: usize,
    pub profile: String,
    pub kv_capacity_blocks: usize,
    pub chunk_budget: usize,
    pub max_batch: usize,
    pub workload: String,
    pub requests: usize,
    pub seed: u64,
    /// Average arrival rate as a fraction of profiled cluster capacity
    /// (§4.1 trace scaling; the paper uses 0.5).
    pub rate_scale: f64,
    pub policy: String,
    /// Policy hyperparameter (λ for linear, Range for filter, T for
    /// Preble, τ-SLO for PolyServe...).
    pub param: f64,
    /// Within-instance queue ordering (`engine::queue` name:
    /// fcfs / srpt / ltr).
    pub queue_policy: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            instances: 16,
            profile: "moe-30b".into(),
            kv_capacity_blocks: 8192,
            chunk_budget: 256,
            max_batch: 64,
            workload: "chatbot".into(),
            requests: 4000,
            seed: 42,
            rate_scale: 0.5,
            policy: "lmetric".into(),
            param: 0.7,
            queue_policy: "fcfs".into(),
        }
    }
}

impl ExperimentConfig {
    /// Build from a parsed document, validating the invariants the
    /// engine cannot express: `chunk_budget == 0` livelocks a busy
    /// instance (the engine debug-asserts; here it is a proper error),
    /// and queue-policy names must exist in the `engine::queue` registry
    /// so typos surface as the name-listing error, not a panic.
    pub fn from_doc(doc: &ConfigDoc) -> Result<ExperimentConfig, String> {
        let mut c = ExperimentConfig::default();
        if let Some(v) = doc.get_usize("cluster", "instances") {
            c.instances = v;
        }
        if let Some(v) = doc.get("cluster", "profile") {
            c.profile = v.to_string();
        }
        if let Some(v) = doc.get_usize("cluster", "kv_capacity_blocks") {
            c.kv_capacity_blocks = v;
        }
        if let Some(v) = doc.get_usize("cluster", "chunk_budget") {
            c.chunk_budget = v;
        }
        if let Some(v) = doc.get_usize("cluster", "max_batch") {
            c.max_batch = v;
        }
        if let Some(v) = doc.get("cluster", "queue_policy") {
            c.queue_policy = v.to_string();
        }
        if let Some(v) = doc.get("trace", "workload") {
            c.workload = v.to_string();
        }
        if let Some(v) = doc.get_usize("trace", "requests") {
            c.requests = v;
        }
        if let Some(v) = doc.get_u64("trace", "seed") {
            c.seed = v;
        }
        if let Some(v) = doc.get_f64("trace", "rate_scale") {
            c.rate_scale = v;
        }
        if let Some(v) = doc.get("policy", "name") {
            c.policy = v.to_string();
        }
        if let Some(v) = doc.get_f64("policy", "param") {
            c.param = v;
        }
        if c.chunk_budget == 0 {
            return Err(
                "cluster.chunk_budget must be >= 1 (a zero budget livelocks a busy \
                 instance: running sequences can never be stepped)"
                    .to_string(),
            );
        }
        // Surface unknown queue-policy names here with the registry's
        // name-listing error rather than panicking at Instance::new.
        crate::engine::queue::build(&c.queue_policy)?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment
[cluster]
instances = 8
profile = "dense-7b"   # dense model
kv_capacity_blocks = 4096

[trace]
workload = "coder"
requests = 100
rate_scale = 0.75

[policy]
name = "linear"
param = 0.55
"#;

    #[test]
    fn parse_sections_and_comments() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("cluster", "profile"), Some("dense-7b"));
        assert_eq!(doc.get_usize("cluster", "instances"), Some(8));
        assert_eq!(doc.get_f64("policy", "param"), Some(0.55));
        assert_eq!(doc.get("nope", "x"), None);
    }

    #[test]
    fn experiment_from_doc_overrides_defaults() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.instances, 8);
        assert_eq!(c.workload, "coder");
        assert_eq!(c.policy, "linear");
        assert_eq!(c.param, 0.55);
        // untouched defaults:
        assert_eq!(c.chunk_budget, 256);
        assert_eq!(c.queue_policy, "fcfs");
    }

    #[test]
    fn experiment_from_doc_reads_queue_policy() {
        let doc = ConfigDoc::parse("[cluster]\nqueue_policy = \"srpt\"").unwrap();
        let c = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(c.queue_policy, "srpt");
    }

    /// Regression (livelock bugfix): the pre-fix config accepted
    /// `chunk_budget = 0` and handed the DES an engine that could never
    /// step a busy instance. It must now be a build-time error.
    #[test]
    fn experiment_from_doc_rejects_zero_chunk_budget() {
        let doc = ConfigDoc::parse("[cluster]\nchunk_budget = 0").unwrap();
        let err = ExperimentConfig::from_doc(&doc).err().unwrap();
        assert!(err.contains("chunk_budget"), "error names the field: {err}");
    }

    #[test]
    fn experiment_from_doc_rejects_unknown_queue_policy_with_listing() {
        let doc = ConfigDoc::parse("[cluster]\nqueue_policy = \"sjf\"").unwrap();
        let err = ExperimentConfig::from_doc(&doc).err().unwrap();
        assert!(err.contains("sjf"), "error names the input: {err}");
        for name in crate::engine::queue::all_names() {
            assert!(err.contains(name), "error lists '{name}': {err}");
        }
    }

    #[test]
    fn bad_line_is_error() {
        assert!(ConfigDoc::parse("[a]\nnot a kv line").is_err());
    }

    #[test]
    fn bools() {
        let doc = ConfigDoc::parse("[s]\na = true\nb = no").unwrap();
        assert_eq!(doc.get_bool("s", "a"), Some(true));
        assert_eq!(doc.get_bool("s", "b"), Some(false));
    }
}
