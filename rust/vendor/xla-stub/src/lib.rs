//! Compile-time stand-in for the `xla` (xla-rs) PJRT bindings.
//!
//! Only the types and methods used by `lmetric`'s `runtime/pjrt.rs` are
//! provided. Host-side [`Literal`] construction works for real (it is pure
//! data); everything that would need the native XLA extension — parsing
//! HLO, compiling, executing — returns [`Error`] with an explanatory
//! message. This keeps the `--features pjrt` build green and the real-PJRT
//! code path warm in CI without a network or the `xla_extension` shared
//! library; swap in the real crate to actually execute (see crate
//! description in Cargo.toml).

use std::fmt;

const UNAVAILABLE: &str = "xla-stub: real PJRT bindings are not vendored in this build; \
     replace the `xla` dependency with the crates.io `xla` crate to execute";

/// Error type mirroring `xla::Error` closely enough for `{e:?}` formatting.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// Element types appearing in the lmetric artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Internal element storage — public only because [`NativeType`]'s
/// methods mention it; not part of the mirrored xla API.
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Sealed-ish element trait for the generic `Literal` constructors.
pub trait NativeType: Copy {
    fn pack(v: &[Self]) -> Data;
    fn unpack(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn pack(v: &[Self]) -> Data {
        Data::F32(v.to_vec())
    }
    fn unpack(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            Data::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn pack(v: &[Self]) -> Data {
        Data::I32(v.to_vec())
    }
    fn unpack(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            Data::F32(_) => None,
        }
    }
}

/// Host literal: real data container (construction/reshape/read work),
/// mirroring `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            data: T::pack(v),
            dims: vec![v.len() as i64],
        }
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            data: T::pack(&[v]),
            dims: vec![],
        }
    }

    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product();
        let data = match ty {
            PrimitiveType::F32 => Data::F32(vec![0.0; n]),
            PrimitiveType::S32 => Data::I32(vec![0; n]),
        };
        Literal {
            data,
            dims: dims.iter().map(|d| *d as i64).collect(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let have: i64 = self.dims.iter().product::<i64>().max(1);
        let want: i64 = dims.iter().product::<i64>().max(1);
        if have != want {
            return Err(Error(format!(
                "reshape: cannot reshape {} elements to {dims:?}",
                have
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unpack(&self.data).ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    /// Destructure a tuple literal — only produced by execution, which the
    /// stub cannot perform.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }
}

/// Parsed HLO module — parsing needs the native extension.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client — construction needs the native plugin.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_construction_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 4);
        assert!(l.reshape(&[3, 3]).is_err());
        let z = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert_eq!(z.to_vec::<f32>().unwrap(), vec![0.0; 6]);
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(s.to_vec::<f32>().is_err());
    }

    #[test]
    fn runtime_entry_points_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }
}
