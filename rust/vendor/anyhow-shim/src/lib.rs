//! A minimal, API-compatible subset of [`anyhow`](https://docs.rs/anyhow):
//! string-backed [`Error`], [`Result`], the [`Context`] extension trait and
//! the [`anyhow!`]/[`bail!`] macros.
//!
//! Vendored because this repository must build from a fresh clone with no
//! network and no pre-populated cargo registry (tier-1 CI contract). The
//! public surface mirrors the real crate closely enough that replacing the
//! `anyhow = { package = "anyhow-shim", path = ... }` dependency with
//! `anyhow = "1"` requires no source changes.
//!
//! Differences from the real crate (acceptable for this codebase):
//! * No backtraces, no downcasting — the error is a context-joined string.
//! * `{e}` and `{e:#}` both render the full context chain.

use std::fmt;

/// A string-backed error with a context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Mirrors anyhow's blanket conversion: any std error can be `?`-propagated
// into an `Error`. `Error` itself intentionally does NOT implement
// `std::error::Error`, exactly like the real crate, so this blanket impl
// does not overlap with the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    #[test]
    fn context_chains() {
        let e = io_fail().unwrap_err();
        let s = format!("{e:#}");
        assert!(s.starts_with("reading config: "), "{s}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad thing {} at {}", 7, "x");
        assert_eq!(format!("{e}"), "bad thing 7 at x");
        let msg = String::from("plain");
        let e2 = anyhow!(msg);
        assert_eq!(format!("{e2}"), "plain");
    }

    #[test]
    fn bail_returns() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero is not allowed (got {x})");
            }
            Ok(x)
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero is not allowed (got 0)");
    }

    #[test]
    fn question_mark_on_io_error() {
        fn f() -> Result<Vec<u8>> {
            let v = std::fs::read("/definitely/not/a/file")?;
            Ok(v)
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing x").unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }
}
